//! Declarative sweep specs: a base [`FleetScenario`] plus axes, expanded
//! into a cross-product of individually-seeded, self-contained [`Cell`]s.
//!
//! Every axis left empty collapses to the base scenario's value, so a
//! spec names only what it varies. Expansion order is fixed (solver →
//! routing → isl → route → walker → interarrival → rate → data size →
//! battery → storage → placement → pipeline → replication, replication
//! innermost),
//! which makes `Cell::index` a
//! stable coordinate: the same spec always yields the same cells in the
//! same order, and [`SweepSpec::cell`] rebuilds any single cell from its
//! index without expanding the rest of the grid.
//!
//! **Seeding.** A cell's RNG seed is derived deterministically from the
//! spec seed and the cell's *replication* coordinate (not the full
//! index): cells that differ only in solver/routing/ISL/… share a seed,
//! so compared configurations see the *same* capture trace and sampled
//! profile — common random numbers, the variance-reduction the old
//! hand-rolled studies got by generating one trace up front. Cells whose
//! workload parameters differ (arrival rate, size bounds, horizon)
//! naturally diverge even under a shared seed. Any cell is reproducible
//! in isolation from its reported `(index, seed)` pair.
//!
//! Specs load from JSON or the TOML subset ([`crate::util::toml`]).
//! Because the TOML subset has no arrays, every axis also accepts a
//! comma-separated string (`solver = "ilpb,arg"`), and single scalars
//! are promoted to one-element axes; the JSON form additionally accepts
//! real arrays.

use crate::config::FleetScenario;
use crate::link::isl::IslMode;
use crate::placement::PlacementPolicy;
use crate::solver::SolverRegistry;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// A Walker delta-pattern coordinate `T/P/F` for the constellation axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalkerAxis {
    /// Total satellites `T`.
    pub sats: usize,
    /// Orbital planes `P`.
    pub planes: usize,
    /// Phasing factor `F`.
    pub phasing: usize,
}

impl WalkerAxis {
    /// Render as the `"T/P/F"` spec string.
    pub fn as_spec(&self) -> String {
        format!("{}/{}/{}", self.sats, self.planes, self.phasing)
    }

    /// Parse `"T/P/F"` (e.g. `"6/3/1"`).
    pub fn parse(text: &str) -> anyhow::Result<WalkerAxis> {
        let parts: Vec<&str> = text.split('/').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "walker axis expects T/P/F (e.g. 6/3/1), got `{text}`"
        );
        let num = |s: &str, what: &str| -> anyhow::Result<usize> {
            s.trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("walker {what} `{s}`: {e}"))
        };
        Ok(WalkerAxis {
            sats: num(parts[0], "T")?,
            planes: num(parts[1], "P")?,
            phasing: num(parts[2], "F")?,
        })
    }
}

/// The swept axes. An empty axis means "use the base scenario's value"
/// (a one-point axis); the cross product of all axes times
/// `replications` is the experiment grid.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Axes {
    /// Solver registry names (`ilpb | dp | exhaustive | arg | ars | greedy`).
    pub solver: Vec<String>,
    /// Routing policy names (see [`FleetScenario::routing_policy`]).
    pub routing: Vec<String>,
    /// ISL pattern (`off | ring | grid`).
    pub isl: Vec<IslMode>,
    /// ISL hop bound ([`FleetScenario::isl_max_hops`]): `0` = bent pipe,
    /// `1` = single-hop relay, larger = multi-hop contact-graph routing.
    pub route: Vec<usize>,
    /// Constellation shape `T/P/F`.
    pub walker: Vec<WalkerAxis>,
    /// Mean capture spacing, seconds (arrival rate = 1/this).
    pub interarrival_s: Vec<f64>,
    /// Satellite-ground rate, Mbps.
    pub rate_mbps: Vec<f64>,
    /// Upper bound of the log-uniform size draw, GB. The lower bound
    /// scales to preserve the base scenario's `lo/hi` ratio, so the axis
    /// shifts the whole distribution rather than just stretching it.
    pub data_gb_hi: Vec<f64>,
    /// Battery capacity, J (0 = unconstrained).
    pub battery_capacity_j: Vec<f64>,
    /// Per-satellite artifact storage budget, MB (0 = unlimited).
    pub storage_mb: Vec<f64>,
    /// Placement policy names (`everywhere | static | demand`).
    pub placement: Vec<String>,
    /// Pipeline execution: `0` disables multi-node pipelines, a value
    /// `>= 2` enables them with at most that many placement nodes
    /// (`1` is rejected — a one-node pipeline is just the legacy split).
    pub pipeline: Vec<usize>,
}

/// Axis names, in expansion order (replication last/innermost). These are
/// the group-by keys [`super::aggregate`] accepts and the per-cell columns
/// the exports carry.
pub const AXIS_NAMES: [&str; 13] = [
    "solver",
    "routing",
    "isl",
    "route",
    "walker",
    "interarrival_s",
    "rate_mbps",
    "data_gb_hi",
    "battery_capacity_j",
    "storage_mb",
    "placement",
    "pipeline",
    "rep",
];

/// A declarative experiment grid over the fleet DES.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// Sweep name (labels exports and progress output).
    pub name: String,
    /// Base seed every cell seed derives from.
    pub seed: u64,
    /// Independent replications per configuration (≥ 1).
    pub replications: usize,
    /// The scenario every cell starts from.
    pub base: FleetScenario,
    /// The swept axes (empty axes collapse to the base's values).
    pub axes: Axes,
}

/// One fully materialized grid point: everything a worker needs to run
/// the cell with zero shared state.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    /// Flat position in the expansion order (the row key of every export).
    pub index: usize,
    /// Replication coordinate (innermost axis).
    pub rep: usize,
    /// RNG seed for this cell's trace + profile draw (see module docs).
    pub seed: u64,
    /// Solver registry name.
    pub solver: String,
    /// The concrete scenario (axes applied to the base).
    pub scenario: FleetScenario,
}

impl Cell {
    /// The cell's value on a named axis, rendered for exports/grouping.
    pub fn axis_value(&self, axis: &str) -> anyhow::Result<String> {
        Ok(match axis {
            "solver" => self.solver.clone(),
            "routing" => self.scenario.routing.clone(),
            "isl" => self.scenario.isl.as_str().to_string(),
            "route" => self.scenario.isl_max_hops.to_string(),
            "walker" => format!(
                "{}/{}/{}",
                self.scenario.sats, self.scenario.planes, self.scenario.phasing
            ),
            "interarrival_s" => format_f64(self.scenario.interarrival_s),
            "rate_mbps" => format_f64(self.scenario.base.rate_mbps),
            "data_gb_hi" => format_f64(self.scenario.data_gb_hi),
            "battery_capacity_j" => format_f64(self.scenario.battery_capacity_j),
            "storage_mb" => format_f64(self.scenario.storage_budget_mb),
            "placement" => self.scenario.placement.clone(),
            "pipeline" => if self.scenario.pipeline {
                self.scenario.pipeline_max_nodes.to_string()
            } else {
                "0".to_string()
            },
            "rep" => self.rep.to_string(),
            other => anyhow::bail!(
                "unknown axis `{other}` ({})",
                AXIS_NAMES.join("|")
            ),
        })
    }
}

/// Deterministic, well-mixed number formatting for exports: shortest
/// round-trip `f64` display (stable across platforms for identical bits).
pub(crate) fn format_f64(x: f64) -> String {
    format!("{x}")
}

/// Derive the seed shared by every cell of replication `rep` (see the
/// module docs for why seeds key on the replication, not the full index).
pub fn replication_seed(base_seed: u64, rep: u64) -> u64 {
    let mut sm = SplitMix64::new(
        base_seed ^ (rep.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    sm.next_u64()
}

/// The resolved (never-empty) axes after base-value defaulting.
struct Resolved {
    solver: Vec<String>,
    routing: Vec<String>,
    isl: Vec<IslMode>,
    route: Vec<usize>,
    walker: Vec<WalkerAxis>,
    interarrival_s: Vec<f64>,
    rate_mbps: Vec<f64>,
    data_gb_hi: Vec<f64>,
    battery_capacity_j: Vec<f64>,
    storage_mb: Vec<f64>,
    placement: Vec<String>,
    pipeline: Vec<usize>,
}

impl SweepSpec {
    /// A one-cell spec over the given base (axes default to base values).
    pub fn point(name: &str, base: FleetScenario) -> SweepSpec {
        SweepSpec {
            name: name.to_string(),
            seed: 42,
            replications: 1,
            base,
            axes: Axes::default(),
        }
    }

    fn resolved(&self) -> Resolved {
        let or = |xs: &[f64], d: f64| if xs.is_empty() { vec![d] } else { xs.to_vec() };
        Resolved {
            solver: if self.axes.solver.is_empty() {
                vec!["ilpb".to_string()]
            } else {
                self.axes.solver.clone()
            },
            routing: if self.axes.routing.is_empty() {
                vec![self.base.routing.clone()]
            } else {
                self.axes.routing.clone()
            },
            isl: if self.axes.isl.is_empty() {
                vec![self.base.isl]
            } else {
                self.axes.isl.clone()
            },
            route: if self.axes.route.is_empty() {
                vec![self.base.isl_max_hops]
            } else {
                self.axes.route.clone()
            },
            walker: if self.axes.walker.is_empty() {
                vec![WalkerAxis {
                    sats: self.base.sats,
                    planes: self.base.planes,
                    phasing: self.base.phasing,
                }]
            } else {
                self.axes.walker.clone()
            },
            interarrival_s: or(&self.axes.interarrival_s, self.base.interarrival_s),
            rate_mbps: or(&self.axes.rate_mbps, self.base.base.rate_mbps),
            data_gb_hi: or(&self.axes.data_gb_hi, self.base.data_gb_hi),
            battery_capacity_j: or(&self.axes.battery_capacity_j, self.base.battery_capacity_j),
            storage_mb: or(&self.axes.storage_mb, self.base.storage_budget_mb),
            placement: if self.axes.placement.is_empty() {
                vec![self.base.placement.clone()]
            } else {
                self.axes.placement.clone()
            },
            pipeline: if self.axes.pipeline.is_empty() {
                vec![if self.base.pipeline {
                    self.base.pipeline_max_nodes
                } else {
                    0
                }]
            } else {
                self.axes.pipeline.clone()
            },
        }
    }

    /// Total number of cells in the grid.
    pub fn len(&self) -> usize {
        let r = self.resolved();
        r.solver.len()
            * r.routing.len()
            * r.isl.len()
            * r.route.len()
            * r.walker.len()
            * r.interarrival_s.len()
            * r.rate_mbps.len()
            * r.data_gb_hi.len()
            * r.battery_capacity_j.len()
            * r.storage_mb.len()
            * r.placement.len()
            * r.pipeline.len()
            * self.replications.max(1)
    }

    /// True for a zero-cell grid (never happens for valid specs).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate every axis value up front so a bad grid fails before any
    /// cell runs (a worker failing mid-sweep on cell 731 of 1024 wastes
    /// everything before it).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.replications >= 1, "replications must be >= 1");
        let r = self.resolved();
        for s in &r.solver {
            SolverRegistry::policy(s)
                .map_err(|e| anyhow::anyhow!("solver axis: {e}"))?;
        }
        for routing in &r.routing {
            let mut probe = self.base.clone();
            probe.routing = routing.clone();
            probe
                .routing_policy()
                .map_err(|e| anyhow::anyhow!("routing axis: {e}"))?;
        }
        for w in &r.walker {
            let mut probe = self.base.clone();
            probe.sats = w.sats;
            probe.planes = w.planes;
            probe.phasing = w.phasing;
            probe
                .pattern()
                .map_err(|e| anyhow::anyhow!("walker axis {}: {e}", w.as_spec()))?;
        }
        for &ia in &r.interarrival_s {
            anyhow::ensure!(
                ia > 0.0 && ia.is_finite(),
                "interarrival_s axis value must be positive and finite, got {ia}"
            );
        }
        for &rate in &r.rate_mbps {
            anyhow::ensure!(
                rate > 0.0 && rate.is_finite(),
                "rate_mbps axis value must be positive and finite, got {rate}"
            );
        }
        for &hi in &r.data_gb_hi {
            let mut probe = self.base.clone();
            apply_data_hi(&mut probe, &self.base, hi);
            probe
                .workload()
                .map_err(|e| anyhow::anyhow!("data_gb_hi axis value {hi}: {e}"))?;
        }
        for &b in &r.battery_capacity_j {
            anyhow::ensure!(
                b >= 0.0 && b.is_finite(),
                "battery_capacity_j axis value must be >= 0 and finite, got {b}"
            );
        }
        for &mb in &r.storage_mb {
            anyhow::ensure!(
                mb >= 0.0 && mb.is_finite(),
                "storage_mb axis value must be >= 0 and finite, got {mb}"
            );
        }
        for p in &r.placement {
            PlacementPolicy::from_name(p)
                .map_err(|e| anyhow::anyhow!("placement axis: {e}"))?;
        }
        for &n in &r.pipeline {
            anyhow::ensure!(
                n != 1,
                "pipeline axis value must be 0 (off) or >= 2 nodes, got 1"
            );
        }
        Ok(())
    }

    /// Materialize cell `index` (row-major over the expansion order).
    /// Panics if `index >= self.len()`; axes are assumed validated.
    pub fn cell(&self, index: usize) -> Cell {
        let r = self.resolved();
        let reps = self.replications.max(1);
        assert!(index < self.len(), "cell index {index} out of range");
        // peel coordinates innermost-first
        let mut rest = index;
        let rep = rest % reps;
        rest /= reps;
        let pipeline = r.pipeline[rest % r.pipeline.len()];
        rest /= r.pipeline.len();
        let placement = &r.placement[rest % r.placement.len()];
        rest /= r.placement.len();
        let storage = r.storage_mb[rest % r.storage_mb.len()];
        rest /= r.storage_mb.len();
        let battery = r.battery_capacity_j[rest % r.battery_capacity_j.len()];
        rest /= r.battery_capacity_j.len();
        let data_hi = r.data_gb_hi[rest % r.data_gb_hi.len()];
        rest /= r.data_gb_hi.len();
        let rate = r.rate_mbps[rest % r.rate_mbps.len()];
        rest /= r.rate_mbps.len();
        let ia = r.interarrival_s[rest % r.interarrival_s.len()];
        rest /= r.interarrival_s.len();
        let walker = r.walker[rest % r.walker.len()];
        rest /= r.walker.len();
        let route = r.route[rest % r.route.len()];
        rest /= r.route.len();
        let isl = r.isl[rest % r.isl.len()];
        rest /= r.isl.len();
        let routing = &r.routing[rest % r.routing.len()];
        rest /= r.routing.len();
        let solver = &r.solver[rest % r.solver.len()];

        let mut scen = self.base.clone();
        scen.name = format!("{}#{index}", self.name);
        scen.routing = routing.clone();
        scen.isl = isl;
        scen.isl_max_hops = route;
        scen.sats = walker.sats;
        scen.planes = walker.planes;
        scen.phasing = walker.phasing;
        scen.interarrival_s = ia;
        scen.base.rate_mbps = rate;
        apply_data_hi(&mut scen, &self.base, data_hi);
        scen.battery_capacity_j = battery;
        scen.storage_budget_mb = storage;
        scen.placement = placement.clone();
        scen.pipeline = pipeline >= 2;
        if pipeline >= 2 {
            scen.pipeline_max_nodes = pipeline;
        }
        Cell {
            index,
            rep,
            seed: replication_seed(self.seed, rep as u64),
            solver: solver.clone(),
            scenario: scen,
        }
    }

    /// Expand the full grid, validating first.
    pub fn expand(&self) -> anyhow::Result<Vec<Cell>> {
        self.validate()?;
        Ok((0..self.len()).map(|i| self.cell(i)).collect())
    }

    /// A CI-sized variant: horizon capped at 6 h, single replication.
    /// Everything else (axes, seeds for rep 0) is unchanged, so a smoke
    /// run exercises the same grid shape the full run would.
    pub fn smoke(mut self) -> SweepSpec {
        self.base.horizon_hours = self.base.horizon_hours.min(6.0);
        self.replications = 1;
        self
    }

    // ------------------------------------------------------------- file io

    /// Serialize the spec (base scenario nested, only non-empty axes).
    pub fn to_json(&self) -> Json {
        let strs = |xs: &[String]| Json::arr(xs.iter().map(|s| Json::str(s.as_str())));
        let nums = |xs: &[f64]| Json::arr(xs.iter().map(|&x| Json::num(x)));
        let mut axes: Vec<(&str, Json)> = Vec::new();
        if !self.axes.solver.is_empty() {
            axes.push(("solver", strs(&self.axes.solver)));
        }
        if !self.axes.routing.is_empty() {
            axes.push(("routing", strs(&self.axes.routing)));
        }
        if !self.axes.isl.is_empty() {
            axes.push((
                "isl",
                Json::arr(self.axes.isl.iter().map(|m| Json::str(m.as_str()))),
            ));
        }
        if !self.axes.route.is_empty() {
            axes.push((
                "route",
                Json::arr(self.axes.route.iter().map(|&h| Json::num(h as f64))),
            ));
        }
        if !self.axes.walker.is_empty() {
            axes.push((
                "walker",
                Json::arr(self.axes.walker.iter().map(|w| Json::str(w.as_spec()))),
            ));
        }
        if !self.axes.interarrival_s.is_empty() {
            axes.push(("interarrival_s", nums(&self.axes.interarrival_s)));
        }
        if !self.axes.rate_mbps.is_empty() {
            axes.push(("rate_mbps", nums(&self.axes.rate_mbps)));
        }
        if !self.axes.data_gb_hi.is_empty() {
            axes.push(("data_gb_hi", nums(&self.axes.data_gb_hi)));
        }
        if !self.axes.battery_capacity_j.is_empty() {
            axes.push(("battery_capacity_j", nums(&self.axes.battery_capacity_j)));
        }
        if !self.axes.storage_mb.is_empty() {
            axes.push(("storage_mb", nums(&self.axes.storage_mb)));
        }
        if !self.axes.placement.is_empty() {
            axes.push(("placement", strs(&self.axes.placement)));
        }
        if !self.axes.pipeline.is_empty() {
            axes.push((
                "pipeline",
                Json::arr(self.axes.pipeline.iter().map(|&n| Json::num(n as f64))),
            ));
        }
        // seeds are full-range u64 and JSON numbers are f64-backed:
        // large seeds serialize as strings so round-trips stay exact
        let seed = if self.seed < (1u64 << 53) {
            Json::num(self.seed as f64)
        } else {
            Json::str(self.seed.to_string())
        };
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("seed", seed),
            ("replications", Json::num(self.replications as f64)),
            ("base", self.base.to_json()),
            ("axes", Json::obj(axes)),
        ])
    }

    /// Read and validate a spec; absent fields take
    /// [`FleetScenario::walker_631`]-based defaults.
    pub fn from_json(v: &Json) -> anyhow::Result<SweepSpec> {
        let base = match v.opt("base") {
            Some(b) => FleetScenario::from_json(b)?,
            None => FleetScenario::walker_631(),
        };
        let axes = match v.opt("axes") {
            Some(a) => Axes {
                solver: str_list(a, "solver")?,
                routing: str_list(a, "routing")?,
                isl: str_list(a, "isl")?
                    .iter()
                    .map(|s| IslMode::from_name(s))
                    .collect::<anyhow::Result<Vec<_>>>()?,
                route: usize_list(a, "route")?,
                walker: str_list(a, "walker")?
                    .iter()
                    .map(|s| WalkerAxis::parse(s))
                    .collect::<anyhow::Result<Vec<_>>>()?,
                interarrival_s: f64_list(a, "interarrival_s")?,
                rate_mbps: f64_list(a, "rate_mbps")?,
                data_gb_hi: f64_list(a, "data_gb_hi")?,
                battery_capacity_j: f64_list(a, "battery_capacity_j")?,
                storage_mb: f64_list(a, "storage_mb")?,
                placement: str_list(a, "placement")?,
                pipeline: usize_list(a, "pipeline")?,
            },
            None => Axes::default(),
        };
        let spec = SweepSpec {
            name: v.str_or("name", "sweep")?.to_string(),
            seed: match v.opt("seed") {
                Some(Json::Str(s)) => s
                    .parse()
                    .map_err(|e| anyhow::anyhow!("seed `{s}`: {e}"))?,
                Some(s) => s.as_u64()?,
                None => 42,
            },
            replications: v.usize_or("replications", 1)?,
            base,
            axes,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Write the spec to `path` as pretty JSON.
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Load from a `.json` file or (by extension) the TOML subset.
    pub fn load(path: &str) -> anyhow::Result<SweepSpec> {
        let text = std::fs::read_to_string(path)?;
        let doc = if path.ends_with(".toml") {
            crate::util::toml::parse(&text)?
        } else {
            Json::parse(&text)?
        };
        SweepSpec::from_json(&doc)
    }
}

/// Shift the log-uniform size distribution to a new upper bound,
/// preserving the base's lo/hi ratio.
fn apply_data_hi(scen: &mut FleetScenario, base: &FleetScenario, hi: f64) {
    let ratio = if base.data_gb_hi > 0.0 {
        base.data_gb_lo / base.data_gb_hi
    } else {
        0.1
    };
    scen.data_gb_hi = hi;
    scen.data_gb_lo = hi * ratio;
}

/// An axis field as strings: accepts a JSON array (of strings), a single
/// string (comma-split — the TOML-subset form), or is absent (empty axis).
fn str_list(v: &Json, key: &str) -> anyhow::Result<Vec<String>> {
    match v.opt(key) {
        None => Ok(Vec::new()),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|i| {
                i.as_str()
                    .map(str::to_string)
                    .map_err(|e| anyhow::anyhow!("axis {key}: {e}"))
            })
            .collect(),
        Some(Json::Str(s)) => Ok(s
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(str::to_string)
            .collect()),
        Some(other) => anyhow::bail!(
            "axis {key}: expected an array or comma-separated string, found {other}"
        ),
    }
}

/// An axis field as whole numbers (the `route` hop bounds and `pipeline`
/// node caps): the numeric forms [`f64_list`] accepts, restricted to
/// non-negative integers.
fn usize_list(v: &Json, key: &str) -> anyhow::Result<Vec<usize>> {
    f64_list(v, key)?
        .into_iter()
        .map(|x| {
            anyhow::ensure!(
                x >= 0.0 && x.fract() == 0.0 && x <= u32::MAX as f64,
                "axis {key}: `{x}` is not a whole non-negative count"
            );
            Ok(x as usize)
        })
        .collect()
}

/// An axis field as numbers: accepts a JSON array (of numbers), a single
/// number, or a comma-separated string of numbers (the TOML-subset form).
fn f64_list(v: &Json, key: &str) -> anyhow::Result<Vec<f64>> {
    match v.opt(key) {
        None => Ok(Vec::new()),
        Some(Json::Num(x)) => Ok(vec![*x]),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|i| i.as_f64().map_err(|e| anyhow::anyhow!("axis {key}: {e}")))
            .collect(),
        Some(Json::Str(s)) => s
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(|p| {
                p.parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("axis {key}: `{p}` is not a number: {e}"))
            })
            .collect(),
        Some(other) => anyhow::bail!(
            "axis {key}: expected an array, number, or comma-separated string, found {other}"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SweepSpec {
        let mut base = FleetScenario::walker_631();
        base.sats = 4;
        base.planes = 2;
        base.horizon_hours = 4.0;
        base.interarrival_s = 1200.0;
        SweepSpec {
            name: "test-grid".to_string(),
            seed: 7,
            replications: 2,
            base,
            axes: Axes {
                solver: vec!["ilpb".into(), "arg".into()],
                routing: vec!["round-robin".into(), "least-loaded".into()],
                ..Axes::default()
            },
        }
    }

    #[test]
    fn cross_product_size_and_order_are_stable() {
        let spec = small_spec();
        assert_eq!(spec.len(), 2 * 2 * 2);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 8);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            // rebuilding any cell standalone reproduces it exactly
            assert_eq!(*c, spec.cell(i));
        }
        // replication is the innermost axis
        assert_eq!(cells[0].rep, 0);
        assert_eq!(cells[1].rep, 1);
        assert_eq!(cells[0].solver, cells[1].solver);
        assert_eq!(cells[0].scenario.routing, cells[1].scenario.routing);
        // solver is the outermost axis
        assert_eq!(cells[0].solver, "ilpb");
        assert_eq!(cells[7].solver, "arg");
    }

    #[test]
    fn seeds_pair_configurations_by_replication() {
        let cells = small_spec().expand().unwrap();
        // same rep ⇒ same seed across every configuration (common random
        // numbers), different reps ⇒ different seeds
        for c in &cells {
            assert_eq!(c.seed, replication_seed(7, c.rep as u64));
        }
        assert_ne!(cells[0].seed, cells[1].seed);
        assert_eq!(cells[0].seed, cells[2].seed);
        // a different base seed moves every cell seed
        let mut other = small_spec();
        other.seed = 8;
        assert_ne!(other.cell(0).seed, cells[0].seed);
    }

    #[test]
    fn empty_axes_collapse_to_the_base() {
        let spec = SweepSpec::point("point", FleetScenario::walker_631());
        assert_eq!(spec.len(), 1);
        let c = spec.expand().unwrap().remove(0);
        assert_eq!(c.solver, "ilpb");
        assert_eq!(c.scenario.routing, "least-loaded");
        assert_eq!(c.scenario.sats, 6);
        assert_eq!(c.scenario.isl, IslMode::Off);
        assert_eq!(c.scenario.isl_max_hops, 4, "base hop bound carries through");
    }

    #[test]
    fn route_axis_sweeps_the_hop_bound() {
        let mut spec = SweepSpec::point("hops", FleetScenario::walker_631());
        spec.base.isl = IslMode::Grid;
        spec.axes.route = vec![0, 1, 4];
        assert_eq!(spec.len(), 3);
        let cells = spec.expand().unwrap();
        let bounds: Vec<usize> = cells.iter().map(|c| c.scenario.isl_max_hops).collect();
        assert_eq!(bounds, vec![0, 1, 4]);
        assert_eq!(cells[2].axis_value("route").unwrap(), "4");
        // every cell still shares the replication seed (common random
        // numbers across hop bounds)
        assert!(cells.iter().all(|c| c.seed == cells[0].seed));
        // fractional or negative hop counts are refused at parse time
        let doc = Json::parse(r#"{"axes": {"route": [1.5]}}"#).unwrap();
        assert!(SweepSpec::from_json(&doc).is_err());
        let doc = Json::parse(r#"{"axes": {"route": "2,3"}}"#).unwrap();
        assert_eq!(SweepSpec::from_json(&doc).unwrap().axes.route, vec![2, 3]);
    }

    #[test]
    fn data_axis_preserves_the_lo_hi_ratio() {
        let mut spec = SweepSpec::point("d", FleetScenario::walker_631());
        // base: 0.5..8.0 GB ⇒ ratio 1/16
        spec.axes.data_gb_hi = vec![16.0];
        let c = spec.expand().unwrap().remove(0);
        assert_eq!(c.scenario.data_gb_hi, 16.0);
        assert!((c.scenario.data_gb_lo - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_axis_values() {
        let mut s = small_spec();
        s.axes.solver.push("simplex".into());
        assert!(s.expand().is_err(), "unknown solver");
        let mut s = small_spec();
        s.axes.routing.push("telepathy".into());
        assert!(s.expand().is_err(), "unknown routing");
        let mut s = small_spec();
        s.axes.walker = vec![WalkerAxis {
            sats: 7,
            planes: 3,
            phasing: 1,
        }];
        assert!(s.expand().is_err(), "indivisible walker");
        let mut s = small_spec();
        s.axes.interarrival_s = vec![0.0];
        assert!(s.expand().is_err(), "zero spacing");
        let mut s = small_spec();
        s.axes.data_gb_hi = vec![-2.0];
        assert!(s.expand().is_err(), "negative size bound");
        let mut s = small_spec();
        s.replications = 0;
        assert!(s.expand().is_err(), "zero replications");
        assert!(WalkerAxis::parse("6/3").is_err());
        assert!(WalkerAxis::parse("a/b/c").is_err());
    }

    #[test]
    fn json_roundtrip_preserves_the_grid() {
        let spec = small_spec();
        let back = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        assert_eq!(spec.expand().unwrap(), back.expand().unwrap());
        // full-range seeds survive the f64-backed JSON number path
        let mut big = small_spec();
        big.seed = u64::MAX - 3;
        let text = big.to_json().to_string_pretty();
        let back = SweepSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.seed, big.seed, "large seeds must round-trip exactly");
    }

    #[test]
    fn toml_subset_accepts_comma_lists() {
        let toml = r#"
name = "toml-sweep"
seed = 11
replications = 2

[axes]
solver = "ilpb, arg"
isl = "off,grid"
route = "1, 4"
walker = "4/2/1, 8/4/1"
interarrival_s = "900, 1800"
rate_mbps = 55

[base]
sats = 4
planes = 2
horizon_hours = 6.0
"#;
        let path = std::env::temp_dir().join("leo_infer_sweep_test.toml");
        let path = path.to_str().unwrap();
        std::fs::write(path, toml).unwrap();
        let spec = SweepSpec::load(path).unwrap();
        let _ = std::fs::remove_file(path);
        assert_eq!(spec.name, "toml-sweep");
        assert_eq!(spec.axes.solver, vec!["ilpb", "arg"]);
        assert_eq!(spec.axes.isl, vec![IslMode::Off, IslMode::Grid]);
        assert_eq!(spec.axes.route, vec![1, 4]);
        assert_eq!(spec.axes.walker[1].sats, 8);
        assert_eq!(spec.axes.interarrival_s, vec![900.0, 1800.0]);
        assert_eq!(spec.axes.rate_mbps, vec![55.0]);
        // 2 solvers × 2 isl × 2 route × 2 walker × 2 interarrival × 2 reps
        assert_eq!(spec.len(), 64);
    }

    #[test]
    fn smoke_caps_horizon_and_replications() {
        let spec = small_spec().smoke();
        assert_eq!(spec.replications, 1);
        assert!(spec.base.horizon_hours <= 6.0);
        assert_eq!(spec.len(), 4);
        // rep-0 seeds unchanged: smoke cells reproduce full-run cells
        assert_eq!(spec.cell(0).seed, replication_seed(7, 0));
    }

    #[test]
    fn placement_axis_sweeps_storage_and_policy() {
        let mut spec = SweepSpec::point("cache", FleetScenario::walker_631());
        spec.axes.storage_mb = vec![0.0, 150.0];
        spec.axes.placement = vec!["everywhere".into(), "demand".into()];
        assert_eq!(spec.len(), 4);
        let cells = spec.expand().unwrap();
        // placement is the inner of the two new axes
        assert_eq!(cells[0].scenario.placement, "everywhere");
        assert_eq!(cells[1].scenario.placement, "demand");
        assert_eq!(cells[0].scenario.storage_budget_mb, 0.0);
        assert_eq!(cells[2].scenario.storage_budget_mb, 150.0);
        assert_eq!(cells[3].axis_value("storage_mb").unwrap(), "150");
        assert_eq!(cells[3].axis_value("placement").unwrap(), "demand");
        // common random numbers across cache configurations
        assert!(cells.iter().all(|c| c.seed == cells[0].seed));
        // bad axis values are refused before any cell runs
        let mut bad = SweepSpec::point("bad", FleetScenario::walker_631());
        bad.axes.placement = vec!["gossip".into()];
        assert!(bad.expand().is_err(), "unknown placement policy");
        let mut neg = SweepSpec::point("neg", FleetScenario::walker_631());
        neg.axes.storage_mb = vec![-1.0];
        assert!(neg.expand().is_err(), "negative storage budget");
    }

    #[test]
    fn pipeline_axis_arms_multi_node_execution() {
        let mut spec = SweepSpec::point("pipe", FleetScenario::walker_631());
        spec.base.isl = IslMode::Grid;
        spec.axes.pipeline = vec![0, 2, 4];
        assert_eq!(spec.len(), 3);
        let cells = spec.expand().unwrap();
        assert!(!cells[0].scenario.pipeline, "0 keeps pipelines off");
        assert!(cells[1].scenario.pipeline && cells[2].scenario.pipeline);
        assert_eq!(cells[1].scenario.pipeline_max_nodes, 2);
        assert_eq!(cells[2].scenario.pipeline_max_nodes, 4);
        assert_eq!(cells[0].axis_value("pipeline").unwrap(), "0");
        assert_eq!(cells[2].axis_value("pipeline").unwrap(), "4");
        // common random numbers across pipeline configurations
        assert!(cells.iter().all(|c| c.seed == cells[0].seed));
        // a one-node "pipeline" is refused up front
        let mut bad = SweepSpec::point("bad", FleetScenario::walker_631());
        bad.axes.pipeline = vec![1];
        assert!(bad.expand().is_err(), "pipeline=1 must be rejected");
        // empty axis collapses to the base scenario's (off) setting,
        // and the JSON round-trip preserves the axis
        let spec2 = SweepSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, spec2);
        assert_eq!(spec2.axes.pipeline, vec![0, 2, 4]);
        let doc = Json::parse(r#"{"axes": {"pipeline": "0, 3"}}"#).unwrap();
        assert_eq!(
            SweepSpec::from_json(&doc).unwrap().axes.pipeline,
            vec![0, 3]
        );
    }

    #[test]
    fn axis_value_covers_every_axis() {
        let c = small_spec().cell(0);
        for axis in AXIS_NAMES {
            assert!(c.axis_value(axis).is_ok(), "axis {axis}");
        }
        assert!(c.axis_value("flux-capacitor").is_err());
        assert_eq!(c.axis_value("walker").unwrap(), "4/2/1");
        assert_eq!(c.axis_value("rep").unwrap(), "0");
    }
}
