//! Paper-figure regeneration: the sweep logic shared by the `cargo bench`
//! harnesses and the CLI's `figures` subcommand.
//!
//! Each function reproduces one figure of §V-B: same sweep variable, same
//! three algorithms (ILPB / ARG / ARS), means over independently
//! randomized scenarios (the paper's parameter draws), energy and time
//! reported separately (the paper plots log-scaled values; we emit raw and
//! log₁₀ columns).

use crate::config::Scenario;
use crate::dnn::profile::ModelProfile;
use crate::solver::engine::{SolverEngine, SolverRegistry};
use crate::solver::policy::OffloadPolicy;
use crate::util::rng::Pcg64;
use crate::util::stats::{mean, Summary};

/// Registry keys of the three algorithms every paper figure compares.
const FIGURE_POLICIES: [&str; 3] = ["ilpb", "arg", "ars"];

/// Per-algorithm aggregate at one sweep point.
#[derive(Debug, Clone)]
pub struct AlgoPoint {
    /// Algorithm registry name (`ilpb | arg | ars`).
    pub name: &'static str,
    /// Energy consumption across the seeds, J.
    pub energy_j: Summary,
    /// Completion time across the seeds, s.
    pub time_s: Summary,
    /// Objective value `Z` across the seeds.
    pub z: Summary,
    /// Mean chosen split (diagnostic; 0 for ARG, K for ARS).
    pub mean_split: f64,
}

/// One x-axis point of a figure.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// The sweep variable's value (GB, Mbps, or λ).
    pub x: f64,
    /// One aggregate per compared algorithm.
    pub algos: Vec<AlgoPoint>,
}

/// Evaluate the three paper algorithms at one scenario configuration
/// across `seeds` independent draws.
pub fn evaluate_point(base: &Scenario, x: f64, seeds: u64, seed0: u64) -> SweepPoint {
    let engines: Vec<SolverEngine> = FIGURE_POLICIES
        .iter()
        .map(|name| SolverRegistry::engine(name).expect("registered policy"))
        .collect();
    let n = engines.len();
    let mut energy: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut time: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut zval: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut splits: Vec<Vec<f64>> = vec![Vec::new(); n];

    for seed in 0..seeds {
        let mut rng = Pcg64::new(seed0 ^ seed, 42);
        let scen = base.clone().randomized(&mut rng);
        // sweeps pin their variable AFTER randomization
        let scen = pin(base, scen, x);
        let profile = ModelProfile::sampled(scen.depth, &mut rng);
        let inst = scen
            .instance_builder(profile)
            .build()
            .expect("scenario must be valid");
        for (i, e) in engines.iter().enumerate() {
            let d = e.decide(&inst);
            energy[i].push(d.costs.energy.value());
            time[i].push(d.costs.latency.value());
            zval[i].push(d.z);
            splits[i].push(d.split as f64);
        }
    }

    SweepPoint {
        x,
        algos: engines
            .iter()
            .enumerate()
            .map(|(i, e)| AlgoPoint {
                name: e.policy_name(),
                energy_j: Summary::of(&energy[i]),
                time_s: Summary::of(&time[i]),
                z: Summary::of(&zval[i]),
                mean_split: mean(&splits[i]),
            })
            .collect(),
    }
}

/// Re-pin the sweep variable on a randomized scenario. The `base`
/// scenario's *name* encodes which figure is being swept.
fn pin(base: &Scenario, mut scen: Scenario, x: f64) -> Scenario {
    match base.name.as_str() {
        "fig2" => scen.data_gb = x,
        "fig3" => {
            scen.rate_mbps = x;
            scen.data_gb = base.data_gb;
        }
        "fig4" => {
            scen.lambda = x;
            scen.mu = 1.0 - x;
            scen.data_gb = base.data_gb;
        }
        _ => scen.data_gb = x,
    }
    scen
}

/// Fig. 2: energy/time vs initial data size, D ∈ [1, 1000] GB.
pub fn fig2(seeds: u64) -> Vec<SweepPoint> {
    let mut base = Scenario::tiansuan();
    base.name = "fig2".into();
    [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0]
        .iter()
        .map(|&gb| evaluate_point(&base, gb, seeds, 0xF16_2))
        .collect()
}

/// Fig. 3: energy/time vs link rate, R ∈ [10, 100] Mbps step 10
/// (D fixed at the paper's mid-scale 100 GB).
pub fn fig3(seeds: u64) -> Vec<SweepPoint> {
    let mut base = Scenario::tiansuan();
    base.name = "fig3".into();
    (1..=10)
        .map(|i| evaluate_point(&base, 10.0 * i as f64, seeds, 0xF16_3))
        .collect()
}

/// Fig. 4: energy/time vs weight ratio λ:μ ∈ {1:0, 3:1, 1:1, 1:3, 0:1}.
pub fn fig4(seeds: u64) -> Vec<SweepPoint> {
    let mut base = Scenario::tiansuan();
    base.name = "fig4".into();
    [1.0, 0.75, 0.5, 0.25, 0.0]
        .iter()
        .map(|&lambda| evaluate_point(&base, lambda, seeds, 0xF16_4))
        .collect()
}

/// The headline metric: ILPB's combined (Z-weighted raw) cost as a
/// fraction of the ARG/ARS average, geometric-mean'd across the Fig-2
/// sweep. The paper claims 10%–18%.
pub fn headline_ratio(points: &[SweepPoint]) -> (f64, f64) {
    let mut e_ratios = Vec::new();
    let mut t_ratios = Vec::new();
    for p in points {
        let ilpb = p.algos.iter().find(|a| a.name == "ILPB").unwrap();
        let arg = p.algos.iter().find(|a| a.name == "ARG").unwrap();
        let ars = p.algos.iter().find(|a| a.name == "ARS").unwrap();
        let e_avg = 0.5 * (arg.energy_j.mean + ars.energy_j.mean);
        let t_avg = 0.5 * (arg.time_s.mean + ars.time_s.mean);
        if e_avg > 0.0 {
            e_ratios.push(ilpb.energy_j.mean / e_avg);
        }
        t_ratios.push(ilpb.time_s.mean / t_avg);
    }
    (
        crate::util::stats::geomean(&e_ratios),
        crate::util::stats::geomean(&t_ratios),
    )
}

/// Render a figure as the paper-shaped table (x, then per-algo log10 E
/// and log10 T columns).
pub fn render_table(title: &str, x_label: &str, points: &[SweepPoint]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = write!(s, "{x_label:>10}");
    for a in &points[0].algos {
        let _ = write!(s, " | {:>10} {:>10}", format!("E[{}]", a.name), format!("T[{}]", a.name));
    }
    let _ = writeln!(s, "   (log10 J / log10 s)");
    for p in points {
        let _ = write!(s, "{:>10.2}", p.x);
        for a in &p.algos {
            let _ = write!(
                s,
                " | {:>10.3} {:>10.3}",
                a.energy_j.mean.max(1e-12).log10(),
                a.time_s.mean.max(1e-12).log10()
            );
        }
        let _ = writeln!(s);
    }
    s
}

/// Serialize sweep points to JSON (machine-readable figure data for
/// external plotting; `leo-infer figures --json <path>`).
pub fn to_json(figure: &str, x_label: &str, points: &[SweepPoint]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("figure", Json::str(figure)),
        ("x_label", Json::str(x_label)),
        (
            "points",
            Json::arr(points.iter().map(|p| {
                Json::obj(vec![
                    ("x", Json::num(p.x)),
                    (
                        "algos",
                        Json::arr(p.algos.iter().map(|a| {
                            Json::obj(vec![
                                ("name", Json::str(a.name)),
                                ("energy_mean_j", Json::num(a.energy_j.mean)),
                                ("energy_ci95", Json::num(a.energy_j.ci95)),
                                ("time_mean_s", Json::num(a.time_s.mean)),
                                ("time_ci95", Json::num(a.time_s.ci95)),
                                ("z_mean", Json::num(a.z.mean)),
                                ("mean_split", Json::num(a.mean_split)),
                            ])
                        })),
                    ),
                ])
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shape_and_monotonicity() {
        let pts = fig2(8);
        assert_eq!(pts.len(), 10);
        for p in &pts {
            assert_eq!(p.algos.len(), 3);
        }
        // energy and time grow with data size for every algorithm
        for algo in 0..3 {
            for pair in pts.windows(2) {
                assert!(
                    pair[1].algos[algo].time_s.mean >= pair[0].algos[algo].time_s.mean * 0.5,
                    "{}: time should broadly grow with D",
                    pts[0].algos[algo].name
                );
            }
        }
    }

    #[test]
    fn ilpb_dominates_in_z() {
        for p in fig2(8) {
            let z = |n: &str| p.algos.iter().find(|a| a.name == n).unwrap().z.mean;
            assert!(z("ILPB") <= z("ARG") + 1e-9, "x={}", p.x);
            assert!(z("ILPB") <= z("ARS") + 1e-9, "x={}", p.x);
        }
    }

    #[test]
    fn fig3_ars_rate_insensitive() {
        // the paper: ARS energy unaffected by link rate
        let pts = fig3(8);
        let ars_e: Vec<f64> = pts
            .iter()
            .map(|p| p.algos.iter().find(|a| a.name == "ARS").unwrap().energy_j.mean)
            .collect();
        let spread = (ars_e.iter().cloned().fold(f64::MIN, f64::max)
            - ars_e.iter().cloned().fold(f64::MAX, f64::min))
            / ars_e[0];
        assert!(spread < 0.25, "ARS energy should be ~flat across rates: {ars_e:?}");
        // ARG time falls as rate rises
        let arg_t: Vec<f64> = pts
            .iter()
            .map(|p| p.algos.iter().find(|a| a.name == "ARG").unwrap().time_s.mean)
            .collect();
        assert!(
            arg_t.last().unwrap() < arg_t.first().unwrap(),
            "ARG time should fall with rate: {arg_t:?}"
        );
    }

    #[test]
    fn fig4_extremes_match_paper() {
        let pts = fig4(16);
        // λ:μ = 1:0 → pure latency: ILPB time ≈ best-time baseline
        let p_latency = &pts[0];
        let t = |n: &str| {
            p_latency
                .algos
                .iter()
                .find(|a| a.name == n)
                .unwrap()
                .time_s
                .mean
        };
        assert!(t("ILPB") <= t("ARG") + 1e-9);
        assert!(t("ILPB") <= t("ARS") + 1e-9);
        // λ:μ = 0:1 → pure energy: ILPB energy ≤ both
        let p_energy = pts.last().unwrap();
        let e = |n: &str| {
            p_energy
                .algos
                .iter()
                .find(|a| a.name == n)
                .unwrap()
                .energy_j
                .mean
        };
        assert!(e("ILPB") <= e("ARG") + 1e-9);
        assert!(e("ILPB") <= e("ARS") + 1e-9);
    }

    #[test]
    fn headline_ratio_is_below_one() {
        let pts = fig2(8);
        let (e_ratio, t_ratio) = headline_ratio(&pts);
        assert!(e_ratio < 1.0, "ILPB energy ratio {e_ratio}");
        assert!(t_ratio < 1.0, "ILPB time ratio {t_ratio}");
    }

    #[test]
    fn render_table_contains_rows() {
        let pts = fig3(2);
        let table = render_table("Fig 3", "rate", &pts);
        assert!(table.contains("ILPB"));
        assert_eq!(table.lines().count(), 2 + pts.len());
    }
}
