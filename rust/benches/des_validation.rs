//! DES-vs-closed-form validation (DESIGN.md per-experiment index).
//!
//! The paper evaluates Eq. 5/8 in closed form. The discrete-event
//! simulator relaxes the closed form's assumptions; this bench quantifies
//! the agreement:
//!
//! 1. **idle, window-aligned** — single request at t = 0: simulated
//!    latency/energy must match Eq. 5/8 exactly for payloads within one
//!    contact window, and differ by exactly `(w−1)·t_con` beyond (Eq. 3's
//!    documented overcount, see `sim::contact`).
//! 2. **queued** — Poisson traffic: mean simulated latency ≥ closed form
//!    (queueing adds, never subtracts).
//!
//! Run: `cargo bench --bench des_validation`

mod common;

use common::banner;
use leo_infer::config::Scenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::runner::{SimConfig, Simulator};
use leo_infer::sim::workload::{fixed_trace, PoissonWorkload, SizeDist};
use leo_infer::solver::{Ilpb, OffloadPolicy, SolverEngine, SolverRegistry};
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{Bytes, Seconds};

fn config(scen: &Scenario, profile: &ModelProfile) -> SimConfig {
    SimConfig {
        template: scen.instance_builder(profile.clone()),
        profiles: vec![profile.clone()],
        contact: PeriodicContact::new(
            Seconds::from_hours(scen.t_cyc_hours),
            Seconds::from_minutes(scen.t_con_minutes),
        ),
        timing: false,
        // generous: the horizon is enforced now, and the queued-traffic
        // section below must drain completely for the mean-latency
        // comparison against the closed form to stay meaningful
        horizon: Seconds::from_hours(40_000.0),
    }
}

fn main() {
    let mut rng = Pcg64::seeded(0xDE5);
    let profile = ModelProfile::sampled(10, &mut rng);

    banner("idle satellite, window-aligned arrival: DES vs Eq. 5/8");
    println!(
        "{:>8} {:>6} {:>14} {:>14} {:>12} {:>10}",
        "R(Mbps)", "algo", "DES T (s)", "Eq.5 T (s)", "gap (s)", "E match"
    );
    for rate in [10.0, 30.0, 50.0, 70.0, 100.0] {
        let scen = Scenario::tiansuan().with_rate_mbps(rate);
        let engines: Vec<SolverEngine> = ["arg", "ars", "ilpb"]
            .iter()
            .map(|n| SolverRegistry::engine(n).unwrap())
            .collect();
        for engine in &engines {
            let trace = fixed_trace(1, Seconds(0.0), Bytes::from_gb(2.0));
            let result = Simulator::new(config(&scen, &profile))
                .run(&trace, engine)
                .expect("valid trace");
            let rec = &result.metrics.records[0];
            let inst = scen
                .instance_builder(profile.clone())
                .data(Bytes::from_gb(2.0))
                .build()
                .unwrap();
            let closed = inst.evaluate_split(rec.split);
            let gap = closed.latency.value() - rec.latency.value();
            // exact phase-aware expectation: satellite compute first, then
            // the transmission starts at phase T_sat of the contact cycle
            let contact = PeriodicContact::new(
                Seconds::from_hours(scen.t_cyc_hours),
                Seconds::from_minutes(scen.t_con_minutes),
            );
            let expected = if rec.split < inst.depth() {
                let t_sat = closed.t_satellite.value();
                let tx_done = contact.transfer_finish(
                    t_sat,
                    inst.subtask_bytes(rec.split),
                    inst.downlink.rate,
                );
                tx_done + inst.t_gc(rec.split).value() + closed.t_cloud.value()
            } else {
                closed.t_satellite.value()
            };
            let e_match = (rec.energy.value() - closed.energy.value()).abs() < 1e-6;
            assert!(
                (rec.latency.value() - expected).abs() < 1e-6,
                "DES diverged from phase-aware expectation: {} vs {expected}",
                rec.latency.value()
            );
            assert!(e_match, "energy mismatch");
            println!(
                "{:>8.0} {:>6} {:>14.1} {:>14.1} {:>12.1} {:>10}",
                rate,
                engine.policy_name(),
                rec.latency.value(),
                closed.latency.value(),
                gap,
                e_match
            );
        }
    }
    println!(
        "(DES is asserted against the exact phase-aware expectation; the gap \n\
         column shows Eq. 5's deviation: +(w−1)·t_con overcount on window-\n\
         aligned transfers, −(phase wait) when satellite compute shifts the \n\
         transmission start mid-cycle)"
    );

    banner("queued traffic: DES mean latency ≥ closed form (queueing adds)");
    for rate in [20.0, 60.0, 100.0] {
        let scen = Scenario::tiansuan().with_rate_mbps(rate);
        let mut wl_rng = Pcg64::seeded(rate as u64);
        let trace = PoissonWorkload::new(
            1.0 / 7200.0,
            SizeDist::Fixed(Bytes::from_gb(2.0)),
        )
        .generate(Seconds::from_hours(200.0), &mut wl_rng);
        let result = Simulator::new(config(&scen, &profile))
            .run(&trace, &SolverRegistry::engine("ilpb").unwrap())
            .expect("valid trace");
        let inst = scen
            .instance_builder(profile.clone())
            .data(Bytes::from_gb(2.0))
            .build()
            .unwrap();
        let d = Ilpb::default().decide(&inst);
        let des_mean = result.metrics.mean_latency().value();
        println!(
            "R = {rate:>5.0} Mbps: DES mean {des_mean:>12.1} s vs closed {:>12.1} s ({} requests, {} completed)",
            d.costs.latency.value(),
            trace.len(),
            result.metrics.completed(),
        );
    }
    println!("\nOK: the closed-form evaluator used by the figures is validated by simulation.");
}
