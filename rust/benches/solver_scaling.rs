//! Solver scaling + ablations (DESIGN.md §6.1/§6.2).
//!
//! * wall time of ILPB vs the O(K) DP scan vs exhaustive vs the literal
//!   2^K enumeration, across model depths K;
//! * pruning statistics: how much of the 2^K space the branch-and-bound
//!   touches (the paper's "effectively reduces the computational
//!   complexity" claim, quantified);
//! * bounding ablation: ILPB with the admissible bound disabled.
//!
//! Run: `cargo bench --bench solver_scaling`

mod common;

use common::{banner, fmt_time, time_median};
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::solver::bnb::{naive_2k_search, Ilpb};
use leo_infer::solver::{
    DpSolver, Exhaustive, OffloadPolicy, SolveRequest, SolverRegistry,
};
use leo_infer::solver::instance::InstanceBuilder;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::Bytes;

fn instance(k: usize, seed: u64) -> leo_infer::solver::instance::Instance {
    let mut rng = Pcg64::seeded(seed);
    InstanceBuilder::new(ModelProfile::sampled(k, &mut rng))
        .data(Bytes::from_gb(100.0))
        .build()
        .unwrap()
}

fn main() {
    banner("solver wall time vs model depth K (median of 20)");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>14}",
        "K", "ILPB", "DP-scan", "Exhaustive", "naive 2^K"
    );
    for k in [8usize, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        let inst = instance(k, k as u64);
        let t_ilpb = time_median(3, 20, || {
            let _ = Ilpb::default().solve(&inst);
        });
        let t_dp = time_median(3, 20, || {
            let _ = DpSolver.decide(&inst);
        });
        let t_ex = time_median(3, 20, || {
            let _ = Exhaustive.decide(&inst);
        });
        let t_naive = if k <= 20 {
            fmt_time(time_median(1, 5, || {
                let _ = naive_2k_search(&inst);
            }))
        } else {
            "—".to_string()
        };
        println!(
            "{:>6} {:>12} {:>12} {:>12} {:>14}",
            k,
            fmt_time(t_ilpb),
            fmt_time(t_dp),
            fmt_time(t_ex),
            t_naive
        );
    }

    banner("search-space reduction (paper: B&B 'reduces the search space')");
    println!(
        "{:>6} {:>14} {:>10} {:>10} {:>10} {:>16}",
        "K", "2^K leaves", "visited", "evaluated", "pruned", "fraction touched"
    );
    for k in [8usize, 12, 16, 20, 32, 64] {
        let inst = instance(k, 7 + k as u64);
        let (_, stats) = Ilpb::default().solve(&inst);
        let full = (k as f64).exp2();
        println!(
            "{:>6} {:>14.0} {:>10} {:>10} {:>10} {:>15.2e}",
            k,
            full,
            stats.nodes,
            stats.leaves,
            stats.pruned,
            stats.nodes as f64 / full
        );
    }

    banner("bounding ablation (leaves evaluated, 100 random instances)");
    let mut rng = Pcg64::seeded(0xAB1A);
    let (mut with_bound, mut without_bound) = (0u64, 0u64);
    for _ in 0..100 {
        let k = 8 + rng.index(120);
        let inst = instance(k, rng.next_u64());
        let (da, sa) = Ilpb::default().solve(&inst);
        let (db, sb) = Ilpb::default().without_bounding().solve(&inst);
        assert!((da.z - db.z).abs() < 1e-12, "ablation changed the optimum");
        with_bound += sa.leaves;
        without_bound += sb.leaves;
    }
    println!(
        "leaves: {with_bound} with bound vs {without_bound} without ({:.1}% saved), optima identical",
        100.0 * (1.0 - with_bound as f64 / without_bound as f64)
    );

    banner("lightweighting ablation (paper §VI future work): activation wire compression");
    println!(
        "{:>12} {:>10} {:>14} {:>14}",
        "wire factor", "split", "latency (s)", "energy (J)"
    );
    {
        let mut rng = Pcg64::seeded(0x11E7);
        let profile = ModelProfile::sampled(10, &mut rng);
        for (label, f) in [("f32 (1.0)", 1.0), ("f16 (0.5)", 0.5), ("int8 (0.25)", 0.25), ("int4 (0.125)", 0.125)] {
            let inst = InstanceBuilder::new(profile.clone())
                .data(Bytes::from_gb(500.0))
                .rate(leo_infer::util::units::BitsPerSec::from_mbps(10.0))
                .wire_compression(f)
                .build()
                .unwrap();
            let (d, _) = Ilpb::default().solve(&inst);
            println!(
                "{:>12} {:>10} {:>14.1} {:>14.1}",
                label,
                d.split,
                d.costs.latency.value(),
                d.costs.energy.value()
            );
        }
    }

    banner("per-decision latency at the paper's scale (K = 10..40)");
    for k in [10usize, 20, 40] {
        let inst = instance(k, 99 + k as u64);
        let t = time_median(10, 100, || {
            let _ = Ilpb::default().solve(&inst);
        });
        println!("K = {k:<3}  {} per decision", fmt_time(t));
    }

    banner("decision cache on a repeated-instance workload (SolverEngine)");
    // Serving traffic repeats: a batcher flushes fixed payload buckets, a
    // constellation reuses one scenario template. Model it as 2000
    // requests drawn round-robin from 20 distinct instances and measure
    // what the engine's LRU saves over solving every request.
    {
        let distinct: Vec<_> = (0..20).map(|i| instance(256, 1000 + i)).collect();
        let requests: Vec<SolveRequest> = (0..2000)
            .map(|i| SolveRequest::new(distinct[i % distinct.len()].clone()))
            .collect();

        let raw = SolverRegistry::policy("ilpb").unwrap();
        let t_raw = time_median(1, 5, || {
            for r in &requests {
                let _ = raw.decide(&r.instance);
            }
        });

        let t_engine = time_median(1, 5, || {
            let engine = SolverRegistry::engine("ilpb").unwrap();
            for r in &requests {
                let _ = engine.solve(r);
            }
        });

        let engine = SolverRegistry::engine("ilpb").unwrap();
        for r in &requests {
            let _ = engine.solve(r);
        }
        let stats = engine.stats();
        // decisions must be unchanged by the cache
        for (i, r) in requests.iter().enumerate() {
            let cached = engine.solve(r).decision;
            let fresh = raw.decide(&r.instance);
            assert!(
                (cached.z - fresh.z).abs() < 1e-12 && cached.split == fresh.split,
                "request {i}: cache changed the optimum"
            );
        }
        println!(
            "{} requests over {} distinct instances (K = 256):",
            requests.len(),
            distinct.len()
        );
        println!(
            "  solves {}  cache hits {}  → {:.1}% of solves skipped",
            stats.solves,
            stats.cache_hits,
            stats.hit_rate() * 100.0
        );
        println!(
            "  wall: {} uncached vs {} through the engine ({:.1}× speedup), optima identical",
            fmt_time(t_raw),
            fmt_time(t_engine),
            t_raw / t_engine
        );
        assert!(
            stats.hit_rate() >= 0.9,
            "repeated workload must skip ≥90% of solves"
        );
    }
}
