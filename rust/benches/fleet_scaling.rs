//! Fleet DES scaling: wall time as the constellation grows with the
//! per-satellite load held constant (DESIGN.md per-experiment index).
//!
//! Each row runs a Walker fleet over 24 h of Poisson captures whose
//! fleet-wide rate scales with N, so every satellite sees the same
//! offered load; wall time growing ~linearly in N means the simulator
//! costs O(events), not O(N · events) — the Arrival-time cluster refresh
//! is the only O(N) term per event.
//!
//! Run: `cargo bench --bench fleet_scaling`
//!
//! Besides the console tables, the run drops `BENCH_fleet.json` in the
//! working directory (machine-readable rows, same numbers as the tables)
//! so the perf trajectory can be tracked across commits.

mod common;

use common::{banner, fmt_time, time_median};
use leo_infer::config::FleetScenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::sim::fleet::FleetSimulator;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::json::Json;
use leo_infer::util::rng::Pcg64;

fn main() {
    let mut scaling_rows: Vec<Json> = Vec::new();
    let mut isl_rows: Vec<Json> = Vec::new();
    banner("fleet DES scaling (periodic contacts, least-loaded routing, ILPB)");
    println!(
        "{:>5} {:>7} {:>10} {:>9} {:>11} {:>12} {:>12}",
        "sats", "reqs", "completed", "rejected", "unfinished", "wall", "req/s (sim)"
    );
    for (t, p) in [(1usize, 1usize), (2, 1), (6, 3), (12, 3), (24, 6)] {
        let mut scen = FleetScenario::walker_631();
        scen.sats = t;
        scen.planes = p;
        scen.phasing = usize::from(p > 1);
        scen.horizon_hours = 24.0;
        scen.interarrival_s = 3600.0 / t as f64; // constant per-sat load
        scen.data_gb_lo = 0.2;
        scen.data_gb_hi = 2.0;
        let mut rng = Pcg64::seeded(0xF1EE7);
        let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
        let profile = ModelProfile::sampled(10, &mut rng);
        let mut last = None;
        let wall = time_median(1, 3, || {
            let engine = SolverRegistry::engine("ilpb").unwrap();
            let sim = FleetSimulator::new(scen.sim_config(profile.clone()).unwrap());
            last = Some(sim.run(&trace, &engine).expect("valid trace"));
        });
        let result = last.expect("at least one timed run");
        let m = &result.metrics;
        println!(
            "{:>5} {:>7} {:>10} {:>9} {:>11} {:>12} {:>12.0}",
            t,
            trace.len(),
            m.completed(),
            m.rejected(),
            m.unfinished,
            fmt_time(wall),
            trace.len() as f64 / wall
        );
        scaling_rows.push(Json::obj(vec![
            ("sats", Json::num(t as f64)),
            ("planes", Json::num(p as f64)),
            ("requests", Json::num(trace.len() as f64)),
            ("completed", Json::num(m.completed() as f64)),
            ("rejected", Json::num(m.rejected() as f64)),
            ("unfinished", Json::num(m.unfinished as f64)),
            ("wall_s", Json::num(wall)),
            ("req_per_s", Json::num(trace.len() as f64 / wall)),
        ]));
    }
    // ISL overhead: the relay path adds a per-SatDone neighbor scan and
    // two extra events per handoff — it must not change the cost class.
    banner("ISL relay overhead (Walker 12/3/1, relay-aware routing, ILPB)");
    println!(
        "{:>6} {:>7} {:>10} {:>8} {:>12}",
        "isl", "reqs", "completed", "relays", "wall"
    );
    for isl in [
        leo_infer::link::isl::IslMode::Off,
        leo_infer::link::isl::IslMode::Ring,
        leo_infer::link::isl::IslMode::Grid,
    ] {
        let mut scen = FleetScenario::walker_631();
        scen.sats = 12;
        scen.planes = 3;
        scen.phasing = 1;
        scen.horizon_hours = 24.0;
        scen.interarrival_s = 300.0;
        scen.data_gb_lo = 0.2;
        scen.data_gb_hi = 2.0;
        scen.isl = isl;
        scen.routing = "relay-aware".to_string();
        let mut rng = Pcg64::seeded(0xF1EE8);
        let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
        let profile = ModelProfile::sampled(10, &mut rng);
        let mut last = None;
        let wall = time_median(1, 3, || {
            let engine = SolverRegistry::engine("ilpb").unwrap();
            let sim = FleetSimulator::new(scen.sim_config(profile.clone()).unwrap());
            last = Some(sim.run(&trace, &engine).expect("valid trace"));
        });
        let result = last.expect("at least one timed run");
        println!(
            "{:>6} {:>7} {:>10} {:>8} {:>12}",
            isl.as_str(),
            trace.len(),
            result.metrics.completed(),
            result.metrics.relays,
            fmt_time(wall)
        );
        isl_rows.push(Json::obj(vec![
            ("isl", Json::str(isl.as_str())),
            ("requests", Json::num(trace.len() as f64)),
            ("completed", Json::num(result.metrics.completed() as f64)),
            ("relays", Json::num(result.metrics.relays as f64)),
            ("wall_s", Json::num(wall)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::str("fleet_scaling")),
        ("scaling", Json::arr(scaling_rows)),
        ("isl_overhead", Json::arr(isl_rows)),
    ]);
    match std::fs::write("BENCH_fleet.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_fleet.json"),
        Err(e) => println!("\nwarning: could not write BENCH_fleet.json: {e}"),
    }

    println!(
        "\nOK: N=1 matches the single-satellite runner's cost; larger fleets \
         amortize routing and per-satellite telemetry across parallel FIFOs, \
         and ISL relaying stays O(neighbors) per transmit decision."
    );
}
