//! Fleet DES scaling: wall time as the constellation grows with the
//! per-satellite load held constant (DESIGN.md per-experiment index).
//!
//! Each row runs a Walker fleet over 24 h of Poisson captures whose
//! fleet-wide rate scales with N, so every satellite sees the same
//! offered load; wall time growing ~linearly in N means the simulator
//! costs O(events), not O(N · events) — the Arrival-time cluster refresh
//! is the only O(N) term per event.
//!
//! The mega-constellation section drives a Walker 40/40 (1600 satellites,
//! grid ISLs, relay-aware routing) through the hot path twice — route
//! cache on and off — and reports event throughput and the cache hit
//! rate. The two runs must agree on every request outcome (the cache is
//! bit-identical by construction; asserted here too).
//!
//! Run: `cargo bench --bench fleet_scaling`  (add `-- --smoke` for the
//! CI-sized grid: fewer rows, shorter horizons, single rep)
//!
//! Besides the console tables, the run drops `BENCH_fleet.json` in the
//! working directory (machine-readable rows, same numbers as the tables)
//! so the perf trajectory can be tracked across commits.

mod common;

use common::{banner, fmt_time, time_median};
use leo_infer::config::FleetScenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::sim::fleet::FleetSimulator;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::json::Json;
use leo_infer::util::rng::Pcg64;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, reps) = if smoke { (0, 1) } else { (1, 3) };
    let mut scaling_rows: Vec<Json> = Vec::new();
    let mut isl_rows: Vec<Json> = Vec::new();
    let mut mega_rows: Vec<Json> = Vec::new();
    banner("fleet DES scaling (periodic contacts, least-loaded routing, ILPB)");
    println!(
        "{:>5} {:>7} {:>10} {:>9} {:>11} {:>12} {:>12}",
        "sats", "reqs", "completed", "rejected", "unfinished", "wall", "req/s (sim)"
    );
    let full_grid: &[(usize, usize)] = &[(1, 1), (2, 1), (6, 3), (12, 3), (24, 6)];
    let grid = if smoke { &full_grid[..3] } else { full_grid };
    for &(t, p) in grid {
        let mut scen = FleetScenario::walker_631();
        scen.sats = t;
        scen.planes = p;
        scen.phasing = usize::from(p > 1);
        scen.horizon_hours = if smoke { 6.0 } else { 24.0 };
        scen.interarrival_s = 3600.0 / t as f64; // constant per-sat load
        scen.data_gb_lo = 0.2;
        scen.data_gb_hi = 2.0;
        let mut rng = Pcg64::seeded(0xF1EE7);
        let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
        let profile = ModelProfile::sampled(10, &mut rng);
        let mut last = None;
        let wall = time_median(warmup, reps, || {
            let engine = SolverRegistry::engine("ilpb").unwrap();
            let sim = FleetSimulator::new(scen.sim_config(profile.clone()).unwrap());
            last = Some(sim.run(&trace, &engine).expect("valid trace"));
        });
        let result = last.expect("at least one timed run");
        let m = &result.metrics;
        println!(
            "{:>5} {:>7} {:>10} {:>9} {:>11} {:>12} {:>12.0}",
            t,
            trace.len(),
            m.completed(),
            m.rejected(),
            m.unfinished,
            fmt_time(wall),
            trace.len() as f64 / wall
        );
        scaling_rows.push(Json::obj(vec![
            ("sats", Json::num(t as f64)),
            ("planes", Json::num(p as f64)),
            ("requests", Json::num(trace.len() as f64)),
            ("completed", Json::num(m.completed() as f64)),
            ("rejected", Json::num(m.rejected() as f64)),
            ("unfinished", Json::num(m.unfinished as f64)),
            ("wall_s", Json::num(wall)),
            ("req_per_s", Json::num(trace.len() as f64 / wall)),
        ]));
    }
    // ISL overhead: the relay path adds a per-SatDone neighbor scan and
    // two extra events per handoff — it must not change the cost class.
    banner("ISL relay overhead (Walker 12/3/1, relay-aware routing, ILPB)");
    println!(
        "{:>6} {:>7} {:>10} {:>8} {:>12}",
        "isl", "reqs", "completed", "relays", "wall"
    );
    for isl in [
        leo_infer::link::isl::IslMode::Off,
        leo_infer::link::isl::IslMode::Ring,
        leo_infer::link::isl::IslMode::Grid,
    ] {
        let mut scen = FleetScenario::walker_631();
        scen.sats = 12;
        scen.planes = 3;
        scen.phasing = 1;
        scen.horizon_hours = if smoke { 6.0 } else { 24.0 };
        scen.interarrival_s = 300.0;
        scen.data_gb_lo = 0.2;
        scen.data_gb_hi = 2.0;
        scen.isl = isl;
        scen.routing = "relay-aware".to_string();
        let mut rng = Pcg64::seeded(0xF1EE8);
        let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
        let profile = ModelProfile::sampled(10, &mut rng);
        let mut last = None;
        let wall = time_median(warmup, reps, || {
            let engine = SolverRegistry::engine("ilpb").unwrap();
            let sim = FleetSimulator::new(scen.sim_config(profile.clone()).unwrap());
            last = Some(sim.run(&trace, &engine).expect("valid trace"));
        });
        let result = last.expect("at least one timed run");
        println!(
            "{:>6} {:>7} {:>10} {:>8} {:>12}",
            isl.as_str(),
            trace.len(),
            result.metrics.completed(),
            result.metrics.relays,
            fmt_time(wall)
        );
        isl_rows.push(Json::obj(vec![
            ("isl", Json::str(isl.as_str())),
            ("requests", Json::num(trace.len() as f64)),
            ("completed", Json::num(result.metrics.completed() as f64)),
            ("relays", Json::num(result.metrics.relays as f64)),
            ("wall_s", Json::num(wall)),
        ]));
    }

    // Mega-constellation hot path: Walker 40/40 = 1600 satellites on a
    // grid ISL mesh, relay-aware routing (every arrival scans the whole
    // fleet's advertised relay routes). Captures come in synchronized
    // sweeps — bursts of simultaneous requests, the imaging-constellation
    // pattern — so between transmitter writes the route cache turns that
    // scan from 1600 bounded Dijkstras per arrival into 1600 LRU probes.
    banner("mega-constellation hot path (Walker 40/40, grid ISL, relay-aware, ILPB)");
    println!(
        "{:>6} {:>7} {:>10} {:>9} {:>12} {:>11} {:>9}",
        "cache", "reqs", "completed", "events", "wall", "events/s", "hit rate"
    );
    let mut outcomes: Vec<(u64, u64, u64)> = Vec::new();
    for cache_on in [true, false] {
        let mut scen = FleetScenario::walker_631();
        scen.name = "walker-40-40".to_string();
        scen.sats = 1600;
        scen.planes = 40;
        scen.phasing = 1;
        scen.horizon_hours = if smoke { 0.25 } else { 1.0 };
        scen.isl = leo_infer::link::isl::IslMode::Grid;
        scen.routing = "relay-aware".to_string();
        scen.route_cache = cache_on;
        // a capture sweep every minute: 20 simultaneous arrivals per burst
        let mut trace = Vec::new();
        let mut t = 0.0;
        while t < scen.horizon().value() {
            for _ in 0..20 {
                trace.push(leo_infer::sim::workload::Request {
                    id: trace.len() as u64,
                    arrival: leo_infer::util::units::Seconds(t),
                    data: leo_infer::util::units::Bytes::from_gb(0.5),
                    model: 0,
                    class: 0,
                });
            }
            t += 60.0;
        }
        let mut rng = Pcg64::seeded(0xF1EE9);
        let profile = ModelProfile::sampled(10, &mut rng);
        let mut last = None;
        let wall = time_median(0, 1, || {
            let engine = SolverRegistry::engine("ilpb").unwrap();
            let mut cfg = scen.sim_config(profile.clone()).unwrap();
            cfg.timing = true;
            let sim = FleetSimulator::new(cfg);
            last = Some(sim.run(&trace, &engine).expect("valid trace"));
        });
        let result = last.expect("at least one timed run");
        let m = &result.metrics;
        let t = result.timing.expect("timing was requested");
        outcomes.push((m.completed(), m.rejected(), m.unfinished));
        println!(
            "{:>6} {:>7} {:>10} {:>9} {:>12} {:>11.0} {:>8.1}%",
            if cache_on { "on" } else { "off" },
            trace.len(),
            m.completed(),
            t.events,
            fmt_time(wall),
            t.events_per_sec(),
            m.route_cache_hit_rate() * 100.0
        );
        mega_rows.push(Json::obj(vec![
            ("route_cache", Json::Bool(cache_on)),
            ("sats", Json::num(1600.0)),
            ("planes", Json::num(40.0)),
            ("requests", Json::num(trace.len() as f64)),
            ("completed", Json::num(m.completed() as f64)),
            ("events", Json::num(t.events as f64)),
            ("wall_s", Json::num(wall)),
            ("events_per_sec", Json::num(t.events_per_sec())),
            ("route_cache_hits", Json::num(m.route_cache_hits as f64)),
            ("route_cache_misses", Json::num(m.route_cache_misses as f64)),
            ("route_cache_hit_rate", Json::num(m.route_cache_hit_rate())),
        ]));
    }
    assert_eq!(
        outcomes[0], outcomes[1],
        "route cache on/off must agree on every request outcome"
    );

    let report = Json::obj(vec![
        ("bench", Json::str("fleet_scaling")),
        ("smoke", Json::Bool(smoke)),
        ("scaling", Json::arr(scaling_rows)),
        ("isl_overhead", Json::arr(isl_rows)),
        ("walker_40_40", Json::arr(mega_rows)),
    ]);
    match std::fs::write("BENCH_fleet.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_fleet.json"),
        Err(e) => println!("\nwarning: could not write BENCH_fleet.json: {e}"),
    }
    // Smoke runs (the CI path, `cargo bench` runs from rust/) also drop a
    // copy at the repo root, where the committed baseline lives — CI then
    // diffs the two with `leo-infer bench-schema` (shape only, never the
    // machine-dependent numbers).
    if smoke {
        match std::fs::write("../BENCH_fleet.json", report.to_string_pretty()) {
            Ok(()) => println!("wrote ../BENCH_fleet.json (repo-root baseline candidate)"),
            Err(e) => println!("warning: could not write ../BENCH_fleet.json: {e}"),
        }
    }

    println!(
        "\nOK: N=1 matches the single-satellite runner's cost; larger fleets \
         amortize routing and per-satellite telemetry across parallel FIFOs, \
         ISL relaying stays O(neighbors) per transmit decision, and the \
         route cache holds Walker 40/40 to LRU-probe cost per arrival."
    );
}
