//! Sweep-runner scaling: wall-clock vs worker threads on a fixed grid,
//! with the determinism invariant asserted at every width.
//!
//! ```bash
//! cargo bench --bench sweep_scaling
//! ```
//!
//! The grid is embarrassingly parallel (cells share nothing), so the
//! runner should scale near-linearly until the core count or the longest
//! single cell dominates. The bench also re-asserts the subsystem's
//! hard requirement where it matters most — under real contention:
//! every thread width must export byte-identical CSV.

mod common;

use common::{banner, fmt_time, time_median};
use leo_infer::config::FleetScenario;
use leo_infer::exp::{self, Axes, SweepSpec};

fn bench_spec() -> SweepSpec {
    let mut base = FleetScenario::walker_631();
    base.sats = 8;
    base.planes = 4;
    base.phasing = 1;
    base.horizon_hours = 24.0;
    base.interarrival_s = 600.0;
    base.data_gb_lo = 0.05;
    base.data_gb_hi = 0.5;
    SweepSpec {
        name: "sweep-scaling".to_string(),
        seed: 1234,
        replications: 2,
        base,
        axes: Axes {
            solver: vec!["ilpb".into(), "arg".into(), "ars".into(), "greedy".into()],
            routing: vec!["round-robin".into(), "least-loaded".into()],
            ..Axes::default()
        },
    }
}

fn main() {
    let spec = bench_spec();
    banner(&format!(
        "sweep runner scaling — {} cells (4 solvers x 2 routings x 2 reps)",
        spec.len()
    ));

    let reference = exp::to_csv(&exp::run_sweep(&spec, 1).expect("serial sweep"));
    let serial_s = time_median(0, 3, || {
        let _ = exp::run_sweep(&spec, 1).unwrap();
    });

    println!(
        "{:>8} {:>12} {:>9} {:>12}",
        "threads", "median", "speedup", "identical?"
    );
    println!("{:>8} {:>12} {:>9.2} {:>12}", 1, fmt_time(serial_s), 1.0, "ref");
    for threads in [2, 4, 8] {
        let result = exp::run_sweep(&spec, threads).expect("threaded sweep");
        let csv = exp::to_csv(&result);
        assert_eq!(
            csv, reference,
            "{threads}-thread exports must be byte-identical to serial"
        );
        let t = time_median(0, 3, || {
            let _ = exp::run_sweep(&spec, threads).unwrap();
        });
        println!(
            "{:>8} {:>12} {:>9.2} {:>12}",
            threads,
            fmt_time(t),
            serial_s / t,
            "yes"
        );
    }
    println!("\nOK: exports byte-identical at every thread width.");
}
