//! Fig. 3 reproduction: total energy/time consumption vs satellite-ground
//! transmission rate (R ∈ [10, 100] Mbps, step 10), ILPB vs ARG vs ARS.
//!
//! Checked properties (paper §V-B): ILPB ≤ both baselines in Z; ILPB and
//! ARG improve as the rate rises; ARS is rate-insensitive.
//!
//! Run: `cargo bench --bench fig3`

mod common;

use common::banner;
use leo_infer::figures::{fig3, render_table, AlgoPoint};

fn main() {
    let seeds: u64 = std::env::var("SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    banner(&format!("Fig 3 — consumption vs link rate ({seeds} draws/point)"));
    let t0 = std::time::Instant::now();
    let pts = fig3(seeds);
    print!("{}", render_table("Fig 3", "R (Mbps)", &pts));

    banner("paper-shape checks");
    let series = |name: &str, f: fn(&AlgoPoint) -> f64| -> Vec<f64> {
        pts.iter()
            .map(|p| f(p.algos.iter().find(|a| a.name == name).unwrap()))
            .collect()
    };
    let arg_t = series("ARG", |a| a.time_s.mean);
    let ilpb_t = series("ILPB", |a| a.time_s.mean);
    let ars_e = series("ARS", |a| a.energy_j.mean);
    println!(
        "ARG time falls with rate      : {} ({:.3e} → {:.3e} s)",
        arg_t.first() > arg_t.last(),
        arg_t.first().unwrap(),
        arg_t.last().unwrap()
    );
    println!(
        "ILPB time falls with rate     : {} ({:.3e} → {:.3e} s)",
        ilpb_t.first() > ilpb_t.last(),
        ilpb_t.first().unwrap(),
        ilpb_t.last().unwrap()
    );
    let ars_spread = (ars_e.iter().cloned().fold(f64::MIN, f64::max)
        - ars_e.iter().cloned().fold(f64::MAX, f64::min))
        / ars_e[0];
    println!(
        "ARS energy spread across rates: {:.2}% (paper: ~flat)",
        ars_spread * 100.0
    );
    for p in &pts {
        let z = |n: &str| p.algos.iter().find(|a| a.name == n).unwrap().z.mean;
        assert!(z("ILPB") <= z("ARG") + 1e-9 && z("ILPB") <= z("ARS") + 1e-9);
    }
    println!("ILPB ≤ min(ARG, ARS) in Z at every rate: true (asserted)");
    println!("\nbench wall time: {:.2} s", t0.elapsed().as_secs_f64());
}
