//! Shared helpers for the custom bench harnesses (criterion is unavailable
//! offline; each bench is a `harness = false` binary that prints the
//! paper-shaped tables plus timing).

#![allow(dead_code)]

use std::time::Instant;

/// Median wall time of `reps` runs of `f` after `warmup` runs, in seconds.
pub fn time_median<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Pretty time.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Section header.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}
