//! Fig. 2 reproduction: total energy/time consumption vs initial data size
//! (D ∈ [1, 1000] GB), ILPB vs ARG vs ARS, plus the paper's headline
//! "10–18% of avg(ARG, ARS)" ratio and growth-rate fits.
//!
//! Run: `cargo bench --bench fig2` (SEEDS env overrides the 50-draw default)

mod common;

use common::banner;
use leo_infer::figures::{fig2, headline_ratio, render_table};
use leo_infer::util::stats::linreg;

fn main() {
    let seeds: u64 = std::env::var("SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    banner(&format!("Fig 2 — consumption vs data size ({seeds} draws/point)"));
    let t0 = std::time::Instant::now();
    let pts = fig2(seeds);
    print!("{}", render_table("Fig 2", "D (GB)", &pts));

    // dispersion columns (the paper plots point estimates; we add 95% CIs)
    banner("dispersion (95% CI of the mean latency, seconds)");
    for p in &pts {
        print!("{:>8.0} GB", p.x);
        for a in &p.algos {
            print!("  {}: ±{:.2e}", a.name, a.time_s.ci95);
        }
        println!();
    }

    // mean chosen split per point (diagnostic of partial offloading)
    banner("mean ILPB split (partial offloading in action)");
    for p in &pts {
        let ilpb = p.algos.iter().find(|a| a.name == "ILPB").unwrap();
        println!("{:>8.0} GB  split {:.2}", p.x, ilpb.mean_split);
    }

    // the paper's claim: ILPB's slower growth rate with data size
    banner("log-log growth rates (slope of log10 T vs log10 D)");
    let xs: Vec<f64> = pts.iter().map(|p| p.x.log10()).collect();
    for name in ["ILPB", "ARG", "ARS"] {
        let ys: Vec<f64> = pts
            .iter()
            .map(|p| {
                p.algos
                    .iter()
                    .find(|a| a.name == name)
                    .unwrap()
                    .time_s
                    .mean
                    .log10()
            })
            .collect();
        let (_, slope, r2) = linreg(&xs, &ys);
        println!("{name:<5} slope {slope:.3} (r² {r2:.4})");
    }

    banner("headline");
    let (e, t) = headline_ratio(&pts);
    println!(
        "ILPB / avg(ARG, ARS): {:.1}% energy, {:.1}% time   (paper: 10%–18%)",
        e * 100.0,
        t * 100.0
    );
    println!("\nbench wall time: {:.2} s", t0.elapsed().as_secs_f64());
}
