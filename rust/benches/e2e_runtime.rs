//! End-to-end runtime benchmarks on the AOT artifacts:
//!
//! * per-stage PJRT execution latency (batch 1 and 8);
//! * batching amortization (µs per image across physical batch sizes);
//! * split-position cost profile: onboard/cloud compute + wire bytes for
//!   every split;
//! * coordinator overhead: serving throughput with the PJRT executor vs
//!   the instant mock (the difference is the compute; the mock isolates
//!   router+batcher+channel overhead).
//!
//! Requires `make artifacts`. Run: `cargo bench --bench e2e_runtime`

mod common;

use common::{banner, fmt_time, time_median};
use leo_infer::coordinator::admission::AdmissionController;
use leo_infer::coordinator::batcher::BatchPolicy;
use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::coordinator::scheduler::Scheduler;
use leo_infer::coordinator::server::{
    ExecutorFactory, MockExecutor, Server, ServerConfig, StageExecutor,
};
use leo_infer::config::Scenario;
use leo_infer::link::downlink::DownlinkModel;
use leo_infer::runtime::artifacts::Manifest;
use leo_infer::runtime::pjrt::StageRuntime;
use leo_infer::runtime::split::SplitExecutor;
use leo_infer::runtime::tensor::HostTensor;
use leo_infer::sim::workload::Request;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds};

fn main() -> anyhow::Result<()> {
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("artifacts not built (run `make artifacts`); skipping e2e_runtime bench");
        return Ok(());
    };

    banner("per-stage PJRT latency (median of 30)");
    println!("{:>4} {:<10} {:>12} {:>12}", "k", "stage", "batch 1", "batch 8");
    let rt1 = StageRuntime::load("b1", &manifest, 1)?;
    let rt8 = StageRuntime::load("b8", &manifest, 8)?;
    let mut x1 = HostTensor::random(vec![1, 3, 64, 64], 1);
    let mut x8 = HostTensor::random(vec![8, 3, 64, 64], 8);
    for k in 0..rt1.depth() {
        let t1 = time_median(3, 30, || {
            let _ = rt1.run_stage(k, &x1).unwrap();
        });
        let t8 = time_median(3, 30, || {
            let _ = rt8.run_stage(k, &x8).unwrap();
        });
        println!(
            "{:>4} {:<10} {:>12} {:>12}",
            k,
            rt1.stage_meta(k).name,
            fmt_time(t1),
            fmt_time(t8)
        );
        x1 = rt1.run_stage(k, &x1)?;
        x8 = rt8.run_stage(k, &x8)?;
    }

    banner("batching amortization (full forward, per-image)");
    for (batch, rt) in [(1usize, &rt1), (8usize, &rt8)] {
        let input = HostTensor::random(vec![batch, 3, 64, 64], 42);
        let t = time_median(2, 10, || {
            let _ = rt.run_range(0..rt.depth(), input.clone()).unwrap();
        });
        println!(
            "batch {batch}: {} per forward, {} per image",
            fmt_time(t),
            fmt_time(t / batch as f64)
        );
    }

    banner("split-position profile (batch 8, medians of 10)");
    println!(
        "{:>6} {:>12} {:>14} {:>12}",
        "split", "onboard", "wire bytes", "cloud"
    );
    let sat = StageRuntime::load("sat", &manifest, 8)?;
    let cloud = StageRuntime::load("cloud", &manifest, 8)?;
    let exec = SplitExecutor::new(sat, cloud)?;
    let input = HostTensor::random(vec![8, 3, 64, 64], 7);
    for split in 0..=manifest.depth() {
        let mut wire = 0usize;
        let mut sat_s = 0.0;
        let mut cloud_s = 0.0;
        let t = time_median(1, 10, || {
            let (_, s, w, c) = exec.run_split(input.clone(), split).unwrap();
            wire = w;
            sat_s = s;
            cloud_s = c;
        });
        let _ = t;
        println!(
            "{:>6} {:>12} {:>14} {:>12}",
            split,
            fmt_time(sat_s),
            wire,
            fmt_time(cloud_s)
        );
    }

    banner("coordinator overhead (64 requests, batch 8)");
    for (label, mock) in [("mock executor (no compute)", true), ("PJRT executor", false)] {
        let profile = manifest.measured_profile(8)?;
        let scenario = Scenario::tiansuan();
        let scheduler = Scheduler::new(
            scenario.instance_builder(profile.clone()),
            vec![profile],
            SolverRegistry::engine("ilpb")?,
        );
        let m2 = Manifest::load("artifacts")?;
        let factory: ExecutorFactory = if mock {
            Box::new(|| Ok(Box::new(MockExecutor::instant()) as Box<dyn StageExecutor>))
        } else {
            Box::new(move || {
                Ok(Box::new(SplitExecutor::new(
                    StageRuntime::load("satellite", &m2, 8)?,
                    StageRuntime::load("cloud", &m2, 8)?,
                )?) as Box<dyn StageExecutor>)
            })
        };
        let mut server = Server::new(
            ServerConfig {
                routing: RoutingPolicy::RoundRobin,
                batching: BatchPolicy {
                    max_batch: 8,
                    max_wait: Seconds(0.5),
                    expedite_critical: true,
                },
                admission: AdmissionController::default(),
                downlink: DownlinkModel::new(
                    BitsPerSec::from_mbps(55.0),
                    Seconds::from_hours(8.0),
                    Seconds::from_minutes(6.0),
                ),
            },
            scheduler,
            vec![factory],
        );
        let t0 = std::time::Instant::now();
        for id in 0..64u64 {
            server.submit(
                Request {
                    id,
                    arrival: Seconds::ZERO,
                    data: Bytes::from_mb(8.0),
                    model: 0,
                    class: 0,
                },
                Seconds(t0.elapsed().as_secs_f64()),
            )?;
        }
        let completions = server.shutdown(Seconds(1.0))?;
        let wall = t0.elapsed().as_secs_f64();
        let served: usize = completions.iter().map(|c| c.plan.batch.len()).sum();
        println!(
            "{label:<28}: {served} served in {} ({:.0} req/s)",
            fmt_time(wall),
            served as f64 / wall
        );
    }
    Ok(())
}
