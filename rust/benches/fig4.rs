//! Fig. 4 reproduction: total energy/time consumption vs the objective
//! weights λ:μ ∈ {1:0, 3:1, 1:1, 1:3, 0:1}, ILPB vs ARG vs ARS.
//!
//! Checked properties (paper §V-B): at λ:μ = 1:0 ILPB matches the best
//! achievable time; at λ:μ = 0:1 ILPB matches the best achievable energy;
//! as μ grows, ILPB's energy is non-increasing.
//!
//! Run: `cargo bench --bench fig4`

mod common;

use common::banner;
use leo_infer::figures::{fig4, render_table, AlgoPoint, SweepPoint};

fn main() {
    let seeds: u64 = std::env::var("SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    banner(&format!("Fig 4 — consumption vs λ:μ ({seeds} draws/point)"));
    let t0 = std::time::Instant::now();
    let pts = fig4(seeds);
    print!("{}", render_table("Fig 4 (x = λ, μ = 1−λ)", "lambda", &pts));

    banner("paper-shape checks");
    let get = |p: &SweepPoint, n: &str| -> AlgoPoint {
        p.algos.iter().find(|a| a.name == n).cloned().unwrap()
    };
    // λ:μ = 1:0 — pure latency objective
    let p = &pts[0];
    let (ilpb, arg, ars) = (get(p, "ILPB"), get(p, "ARG"), get(p, "ARS"));
    println!(
        "λ=1: ILPB time {:.3e} ≤ min(ARG {:.3e}, ARS {:.3e}): {}",
        ilpb.time_s.mean,
        arg.time_s.mean,
        ars.time_s.mean,
        ilpb.time_s.mean <= arg.time_s.mean.min(ars.time_s.mean) + 1e-6
    );
    // λ:μ = 0:1 — pure energy objective
    let p = pts.last().unwrap();
    let (ilpb, arg, ars) = (get(p, "ILPB"), get(p, "ARG"), get(p, "ARS"));
    println!(
        "μ=1: ILPB energy {:.3e} ≤ min(ARG {:.3e}, ARS {:.3e}): {}",
        ilpb.energy_j.mean,
        arg.energy_j.mean,
        ars.energy_j.mean,
        ilpb.energy_j.mean <= arg.energy_j.mean.min(ars.energy_j.mean) + 1e-6
    );
    // energy monotone as μ grows (left→right in our table = λ falling)
    let e_series: Vec<f64> = pts.iter().map(|p| get(p, "ILPB").energy_j.mean).collect();
    let monotone = e_series.windows(2).all(|w| w[1] <= w[0] * 1.001);
    println!("ILPB energy non-increasing as μ grows: {monotone}");
    for (p, e) in pts.iter().zip(&e_series) {
        println!("  λ={:<5} ILPB energy {:.4e} J", p.x, e);
    }
    println!("\nbench wall time: {:.2} s", t0.elapsed().as_secs_f64());
}
