//! Integration tests over the coordinator: serving pipeline end-to-end
//! with mock executors, failure injection, and routing/batching interplay.

use leo_infer::coordinator::admission::{AdmissionController, AdmissionVerdict};
use leo_infer::coordinator::batcher::BatchPolicy;
use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::coordinator::scheduler::{ExecutionPlan, Scheduler};
use leo_infer::coordinator::server::{
    ExecutionReport, ExecutorFactory, MockExecutor, Server, ServerConfig, StageExecutor,
    SubmitResult,
};
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::link::downlink::DownlinkModel;
use leo_infer::sim::workload::Request;
use leo_infer::solver::instance::InstanceBuilder;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds};

fn profile() -> ModelProfile {
    ModelProfile::from_alphas("net", &[1000.0, 400.0, 120.0, 30.0, 4.0]).unwrap()
}

fn downlink() -> DownlinkModel {
    DownlinkModel::new(
        BitsPerSec::from_mbps(50.0),
        Seconds::from_hours(8.0),
        Seconds::from_minutes(6.0),
    )
}

fn scheduler() -> Scheduler {
    Scheduler::new(
        InstanceBuilder::new(profile()),
        vec![profile()],
        SolverRegistry::engine("ilpb").unwrap(),
    )
}

fn req(id: u64, gb: f64, model: usize, class: u8) -> Request {
    Request {
        id,
        arrival: Seconds::ZERO,
        data: Bytes::from_gb(gb),
        model,
        class,
    }
}

fn mock_factories(n: usize) -> Vec<ExecutorFactory> {
    (0..n)
        .map(|_| {
            Box::new(|| Ok(Box::new(MockExecutor::instant()) as Box<dyn StageExecutor>))
                as ExecutorFactory
        })
        .collect()
}

#[test]
fn thousand_requests_across_four_satellites() {
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::RoundRobin,
            batching: BatchPolicy {
                max_batch: 16,
                max_wait: Seconds(1.0),
                expedite_critical: true,
            },
            admission: AdmissionController {
                queue_cap: 100_000,
                ..Default::default()
            },
            downlink: downlink(),
        },
        scheduler(),
        mock_factories(4),
    );
    let mut rng = Pcg64::seeded(1);
    for id in 0..1000u64 {
        let r = server
            .submit(req(id, rng.uniform(0.1, 10.0), 0, 0), Seconds(id as f64 * 0.001))
            .unwrap();
        assert!(matches!(r, SubmitResult::Accepted { .. }));
        // drain completions as we go (keeps queue_depth bounded)
        let _ = server.poll_completions();
    }
    let completions = server.shutdown(Seconds(10.0)).unwrap();
    // poll_completions consumed some; shutdown returns the rest — total
    // conservation is checked through cluster state reaching zero depth
    let drained: usize = completions.iter().map(|c| c.plan.batch.len()).sum();
    assert!(drained > 0);
}

#[test]
fn conservation_none_lost_none_duplicated() {
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::LeastLoaded,
            batching: BatchPolicy {
                max_batch: 7, // deliberately not dividing the request count
                max_wait: Seconds(1e9),
                expedite_critical: false,
            },
            admission: AdmissionController {
                queue_cap: 10_000,
                ..Default::default()
            },
            downlink: downlink(),
        },
        scheduler(),
        mock_factories(3),
    );
    for id in 0..200u64 {
        server.submit(req(id, 1.0, 0, 0), Seconds(0.0)).unwrap();
    }
    let completions = server.shutdown(Seconds(1.0)).unwrap();
    let mut ids: Vec<u64> = completions
        .iter()
        .flat_map(|c| c.plan.batch.requests.iter().map(|r| r.id))
        .collect();
    ids.sort_unstable();
    let expect: Vec<u64> = (0..200).collect();
    assert_eq!(ids, expect, "every request exactly once");
}

#[test]
fn critical_requests_bypass_batching_delay() {
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::RoundRobin,
            batching: BatchPolicy {
                max_batch: 1000,
                max_wait: Seconds(1e9),
                expedite_critical: true,
            },
            admission: AdmissionController::default(),
            downlink: downlink(),
        },
        scheduler(),
        mock_factories(1),
    );
    server.submit(req(0, 1.0, 0, 0), Seconds(0.0)).unwrap();
    server.submit(req(1, 1.0, 0, 1), Seconds(0.1)).unwrap(); // critical
    // the critical submit must have flushed both
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    let mut got = Vec::new();
    while got.is_empty() && std::time::Instant::now() < deadline {
        got = server.poll_completions();
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].plan.batch.len(), 2);
    let _ = server.shutdown(Seconds(1.0)).unwrap();
}

/// Failure injection: an executor that fails the first N plans.
struct FlakyExecutor {
    failures_left: usize,
    inner: MockExecutor,
}

impl StageExecutor for FlakyExecutor {
    fn execute(&mut self, plan: &ExecutionPlan) -> anyhow::Result<ExecutionReport> {
        if self.failures_left > 0 {
            self.failures_left -= 1;
            anyhow::bail!("injected transient failure");
        }
        self.inner.execute(plan)
    }
}

#[test]
fn executor_failures_do_not_wedge_the_server() {
    let factory: ExecutorFactory = Box::new(|| {
        Ok(Box::new(FlakyExecutor {
            failures_left: 2,
            inner: MockExecutor::instant(),
        }) as Box<dyn StageExecutor>)
    });
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::RoundRobin,
            batching: BatchPolicy {
                max_batch: 1,
                max_wait: Seconds(1.0),
                expedite_critical: true,
            },
            admission: AdmissionController::default(),
            downlink: downlink(),
        },
        scheduler(),
        vec![factory],
    );
    for id in 0..5u64 {
        server.submit(req(id, 1.0, 0, 0), Seconds(0.0)).unwrap();
        let _ = server.poll_completions();
    }
    let completions = server.shutdown(Seconds(1.0)).unwrap();
    // first two plans failed (logged + dropped); remaining three served
    let served: usize = completions.iter().map(|c| c.plan.batch.len()).sum();
    assert!(served >= 3, "server wedged after executor failures");
}

#[test]
fn energy_aware_routing_goes_unroutable_when_fleet_depleted() {
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::EnergyAware { min_soc: 0.5 },
            batching: BatchPolicy::default(),
            admission: AdmissionController::default(),
            downlink: downlink(),
        },
        scheduler(),
        mock_factories(2),
    );
    // drain the fleet's batteries via telemetry
    for id in server.cluster().ids() {
        server.cluster_mut().get_mut(id).unwrap().soc = 0.1;
    }
    let r = server.submit(req(0, 1.0, 0, 0), Seconds(0.0)).unwrap();
    assert_eq!(r, SubmitResult::Unroutable);
    let _ = server.shutdown(Seconds(1.0)).unwrap();
}

#[test]
fn admission_rejects_low_battery_satellite() {
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::RoundRobin,
            batching: BatchPolicy::default(),
            admission: AdmissionController {
                soc_floor: 0.5,
                ..Default::default()
            },
            downlink: downlink(),
        },
        scheduler(),
        mock_factories(1),
    );
    server.cluster_mut().get_mut(0).unwrap().soc = 0.3;
    match server.submit(req(0, 1.0, 0, 0), Seconds(0.0)).unwrap() {
        SubmitResult::Rejected(AdmissionVerdict::BatteryLow { soc, floor }) => {
            assert!(soc < floor);
        }
        other => panic!("expected battery rejection, got {other:?}"),
    }
    let _ = server.shutdown(Seconds(1.0)).unwrap();
}

#[test]
fn multi_model_batches_stay_separated() {
    let profiles = vec![
        profile(),
        ModelProfile::from_alphas("net2", &[1000.0, 10.0, 1.0]).unwrap(),
    ];
    let scheduler = Scheduler::new(
        InstanceBuilder::new(profiles[0].clone()),
        profiles,
        SolverRegistry::engine("ilpb").unwrap(),
    );
    let mut server = Server::new(
        ServerConfig {
            routing: RoutingPolicy::RoundRobin,
            batching: BatchPolicy {
                max_batch: 4,
                max_wait: Seconds(1e9),
                expedite_critical: false,
            },
            admission: AdmissionController::default(),
            downlink: downlink(),
        },
        scheduler,
        mock_factories(1),
    );
    for id in 0..16u64 {
        server
            .submit(req(id, 1.0, (id % 2) as usize, 0), Seconds(0.0))
            .unwrap();
    }
    let completions = server.shutdown(Seconds(1.0)).unwrap();
    for c in &completions {
        let models: Vec<usize> = c.plan.batch.requests.iter().map(|r| r.model).collect();
        assert!(
            models.iter().all(|&m| m == c.plan.batch.model),
            "mixed-model batch: {models:?}"
        );
    }
    let served: usize = completions.iter().map(|c| c.plan.batch.len()).sum();
    assert_eq!(served, 16);
}
