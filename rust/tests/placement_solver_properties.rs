//! Cross-module property tests on multi-node placement vectors: the
//! generalized branch-and-bound against the exhaustive oracle over
//! randomized 2–4-node chains, the two-node reduction against the legacy
//! split solvers at the bit level for every registered policy, and the
//! validation paths that must error — never panic — on malformed input.

use leo_infer::dnn::profile::ModelProfile;
use leo_infer::solver::instance::{Instance, InstanceBuilder};
use leo_infer::solver::{
    decide_for_policy, ExhaustivePlacement, LinkLeg, NodeProfile, Placement, PlacementBnb,
    PlacementInstance, SolverRegistry, Telemetry,
};
use leo_infer::util::proptest::Runner;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds, Watts};

/// A randomized base (satellite/ground) instance with oracle-friendly depth
/// `K ∈ [1, 8]` — small enough that exhaustive placement enumeration stays
/// under `C(8+4, 4) = 495` leaves per case.
fn random_base(rng: &mut Pcg64) -> Instance {
    let k = 1 + rng.index(8);
    InstanceBuilder::new(ModelProfile::sampled(k, rng))
        .data(Bytes::from_gb(rng.uniform(0.1, 100.0)))
        .beta_s_per_kb(rng.uniform(0.01, 0.03))
        .gamma_s_per_kb(rng.uniform(0.0001, 0.001))
        .rate(BitsPerSec::from_mbps(rng.uniform(10.0, 100.0)))
        .contact(
            Seconds::from_hours(rng.uniform(1.0, 24.0)),
            Seconds::from_minutes(rng.uniform(1.0, 10.0)),
        )
        .gpu(
            rng.uniform(10.0, 10000.0),
            Watts(rng.uniform(1.0, 10.0)),
            Watts(rng.uniform(0.01, 1.0)),
            Watts(rng.uniform(0.001, 0.2)),
        )
        .p_off(Watts(rng.uniform(0.5, 12.0)))
        .weights(0.5, 0.5)
        .build()
        .unwrap()
}

/// A randomized chain instance: 2–4 nodes of varied compute scale and
/// readiness, joined by ISL legs of varied rate and propagation delay.
fn random_chain(rng: &mut Pcg64) -> PlacementInstance {
    let base = random_base(rng);
    let m = 2 + rng.index(3);
    let mut nodes = vec![NodeProfile::unit("serving")];
    for j in 1..m {
        nodes.push(NodeProfile::new(
            &format!("relay-{j}"),
            rng.uniform(0.2, 8.0),
            Seconds(rng.uniform(0.0, 2.0)),
        ));
    }
    let legs = (1..m)
        .map(|_| {
            LinkLeg::new(
                BitsPerSec::from_mbps(rng.uniform(50.0, 5000.0)),
                Seconds(rng.uniform(0.0005, 0.02)),
            )
        })
        .collect();
    PlacementInstance::new(base, nodes, legs).unwrap()
}

#[test]
fn bnb_matches_the_exhaustive_oracle_exactly() {
    Runner::new("BnB ε=0 == placement oracle", 300).run(|rng| {
        let pinst = random_chain(rng);
        let oracle = ExhaustivePlacement::solve(&pinst);
        let (bnb, stats) = PlacementBnb::default().solve(&pinst);
        if (bnb.z - oracle.z).abs() > 1e-9 {
            return Err(format!(
                "bnb z {} (cuts {:?}) vs oracle z {} (cuts {:?})",
                bnb.z, bnb.placement.cuts, oracle.z, oracle.placement.cuts
            ));
        }
        if stats.leaves == 0 {
            return Err("search evaluated no complete placement".to_string());
        }
        Ok(())
    });
}

#[test]
fn epsilon_bnb_stays_within_its_slack_of_the_oracle() {
    for (i, eps) in [0.0, 1e-3, 1e-2, 0.1].into_iter().enumerate() {
        Runner::new(&format!("BnB z − oracle ≤ ε at ε={eps}"), 150)
            .seed(0xBEEF + i as u64)
            .run(|rng| {
                let pinst = random_chain(rng);
                let oracle = ExhaustivePlacement::solve(&pinst).z;
                let (d, _) = PlacementBnb { epsilon: eps, bounding: true }.solve(&pinst);
                let gap = d.z - oracle;
                if gap > eps + 1e-9 {
                    return Err(format!("gap {gap} exceeds ε {eps}"));
                }
                if gap < -1e-9 {
                    return Err(format!("BnB beat the exhaustive oracle by {}", -gap));
                }
                Ok(())
            });
    }
}

#[test]
fn unbounded_dfs_replays_the_oracle_bit_for_bit() {
    // With bounding off, the DFS enumerates the same lexicographic leaf
    // order as the oracle with the same strict-improvement rule, so the
    // argmin — and its objective bits — must be identical. Across the
    // corpus the bound must also actually fire when re-enabled.
    let mut pruned_total = 0u64;
    Runner::new("bounding off == oracle bits", 120).run(|rng| {
        let pinst = random_chain(rng);
        let oracle = ExhaustivePlacement::solve(&pinst);
        let (d, stats) = PlacementBnb { epsilon: 0.0, bounding: false }.solve(&pinst);
        if d.placement != oracle.placement {
            return Err(format!(
                "cuts diverged: {:?} vs {:?}",
                d.placement.cuts, oracle.placement.cuts
            ));
        }
        if d.z.to_bits() != oracle.z.to_bits() {
            return Err(format!("z bits diverged: {} vs {}", d.z, oracle.z));
        }
        if stats.pruned != 0 {
            return Err(format!("{} prunes with bounding disabled", stats.pruned));
        }
        let (_, bounded) = PlacementBnb::default().solve(&pinst);
        pruned_total += bounded.pruned;
        Ok(())
    });
    assert!(
        pruned_total > 0,
        "the admissible bound never pruned a subtree across the whole corpus"
    );
}

#[test]
fn two_node_engine_reduction_is_bit_identical_for_every_solver() {
    for name in SolverRegistry::NAMES {
        Runner::new(&format!("two-node identity through `{name}`"), 60).run(|rng| {
            let inst = random_base(rng);
            let tel = Telemetry::unconstrained();
            // Two independent engines: one solves the legacy split problem,
            // the other the lifted two-node placement. No shared cache —
            // the bit match must come from the reduction itself.
            let legacy = SolverRegistry::engine(name)
                .expect("registry name builds")
                .solve_parts(&inst, &tel);
            let placed = SolverRegistry::engine(name)
                .expect("registry name builds")
                .solve_placement(&inst.clone().two_node(), &tel);
            if placed.decision.placement.cuts != vec![legacy.decision.split] {
                return Err(format!(
                    "{name}: cuts {:?} vs split {}",
                    placed.decision.placement.cuts, legacy.decision.split
                ));
            }
            if placed.decision.z.to_bits() != legacy.decision.z.to_bits() {
                return Err(format!(
                    "{name}: z bits drifted ({} vs {})",
                    placed.decision.z, legacy.decision.z
                ));
            }
            Ok(())
        });
    }
}

#[test]
fn two_node_evaluator_matches_legacy_costs_bitwise_at_every_split() {
    Runner::new("evaluate_cuts([s]) == evaluate_split(s) bits", 150).run(|rng| {
        let inst = random_base(rng);
        let pinst = PlacementInstance::two_node(inst.clone());
        let obj = inst.objective();
        for s in 0..=inst.depth() {
            let legacy = inst.evaluate_split(s);
            let c = pinst.evaluate_cuts(&[s]);
            let pairs = [
                ("latency", c.latency.value(), legacy.latency.value()),
                ("energy", c.energy.value(), legacy.energy.value()),
                ("t_downlink", c.t_downlink.value(), legacy.t_downlink.value()),
                ("t_cloud", c.t_cloud.value(), legacy.t_cloud.value()),
                ("e_processing", c.e_processing.value(), legacy.e_processing.value()),
            ];
            for (what, a, b) in pairs {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{what} bits differ at split {s}: {a} vs {b}"));
                }
            }
            let z = obj.z(&c.as_costs());
            if z.to_bits() != inst.z_of_split(s, &obj).to_bits() {
                return Err(format!("z bits differ at split {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn heuristic_lifts_keep_their_legacy_shape() {
    Runner::new("ARG/ARS lifts and exact dominance", 100).run(|rng| {
        let pinst = random_chain(rng);
        let k = pinst.depth();
        let arg = decide_for_policy("ARG", &pinst);
        if arg.placement.cuts.iter().any(|&c| c != 0) {
            return Err(format!("ARG must offload everything, got {:?}", arg.placement.cuts));
        }
        let ars = decide_for_policy("ARS", &pinst);
        if ars.placement.cuts.iter().any(|&c| c != k) {
            return Err(format!("ARS must stay on the chain, got {:?}", ars.placement.cuts));
        }
        let exact = decide_for_policy("Exhaustive", &pinst);
        if exact.z > arg.z + 1e-9 || exact.z > ars.z + 1e-9 {
            return Err(format!(
                "exact z {} worse than a fixed baseline (ARG {}, ARS {})",
                exact.z, arg.z, ars.z
            ));
        }
        Ok(())
    });
}

#[test]
fn invalid_chains_error_instead_of_panicking() {
    let base = || InstanceBuilder::default().build().expect("default instance builds");
    let unit = || NodeProfile::unit("sat");
    let leg = || LinkLeg::new(BitsPerSec::from_mbps(1000.0), Seconds(0.001));

    // Empty node list.
    assert!(PlacementInstance::new(base(), vec![], vec![]).is_err());
    // Leg count mismatch: the second node is unreachable.
    assert!(PlacementInstance::new(base(), vec![unit(), unit()], vec![]).is_err());
    assert!(PlacementInstance::new(base(), vec![unit()], vec![leg()]).is_err());
    // Unreachable legs: NaN, zero and negative serialization rates.
    for bad in [f64::NAN, 0.0, -5.0, f64::INFINITY] {
        let l = LinkLeg::new(BitsPerSec(bad), Seconds(0.001));
        assert!(
            PlacementInstance::new(base(), vec![unit(), unit()], vec![l]).is_err(),
            "leg rate {bad} must be rejected"
        );
    }
    // Broken propagation delays.
    for bad in [f64::NAN, -1.0] {
        let l = LinkLeg::new(BitsPerSec::from_mbps(1000.0), Seconds(bad));
        assert!(
            PlacementInstance::new(base(), vec![unit(), unit()], vec![l]).is_err(),
            "leg propagation {bad} must be rejected"
        );
    }
    // Broken compute scales and readiness offsets.
    for bad in [f64::NAN, 0.0, -2.0] {
        let n = NodeProfile::new("bad", bad, Seconds::ZERO);
        assert!(
            PlacementInstance::new(base(), vec![unit(), n], vec![leg()]).is_err(),
            "compute scale {bad} must be rejected"
        );
    }
    for bad in [f64::NAN, -0.5] {
        let n = NodeProfile::new("bad", 1.0, Seconds(bad));
        assert!(
            PlacementInstance::new(base(), vec![unit(), n], vec![leg()]).is_err(),
            "readiness {bad} must be rejected"
        );
    }
}

#[test]
fn out_of_path_placements_error_instead_of_panicking() {
    let base = InstanceBuilder::default().build().expect("default instance builds");
    let k = base.depth();
    let pinst = PlacementInstance::new(
        base,
        vec![NodeProfile::unit("a"), NodeProfile::new("b", 2.0, Seconds::ZERO)],
        vec![LinkLeg::new(BitsPerSec::from_mbps(1000.0), Seconds(0.001))],
    )
    .unwrap();
    // Wrong vector length (placement names nodes off the path).
    assert!(pinst.evaluate(&Placement { cuts: vec![0] }).is_err());
    assert!(pinst.evaluate(&Placement { cuts: vec![0, 0, 0] }).is_err());
    // Cut beyond the model depth.
    assert!(pinst.evaluate(&Placement { cuts: vec![0, k + 1] }).is_err());
    // Decreasing cuts (a layer assigned upstream of its predecessor).
    assert!(pinst.evaluate(&Placement { cuts: vec![k, 0] }).is_err());
    // A well-formed placement still evaluates.
    assert!(pinst.evaluate(&Placement { cuts: vec![0, k] }).is_ok());
}

#[test]
fn malformed_base_instances_error_at_build_time() {
    // NaN / non-positive rates and coefficients must surface as builder
    // errors long before a placement solver can see them.
    assert!(InstanceBuilder::default().data(Bytes::from_gb(0.0)).build().is_err());
    assert!(InstanceBuilder::default().data(Bytes(-4.0)).build().is_err());
    assert!(InstanceBuilder::default().beta_s_per_kb(0.0).build().is_err());
    assert!(InstanceBuilder::default().beta_s_per_kb(-0.01).build().is_err());
    assert!(InstanceBuilder::default().gamma_s_per_kb(-0.001).build().is_err());
    assert!(InstanceBuilder::default().weights(0.7, 0.7).build().is_err());
    assert!(InstanceBuilder::default().weights(-0.5, 1.5).build().is_err());
}
