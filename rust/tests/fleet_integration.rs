//! Fleet DES integration: N=1 equivalence with the legacy single-satellite
//! simulator, determinism at N>1, event-queue tie-break properties, and the
//! orbit-derived end-to-end path.

use leo_infer::config::{ContactSource, FleetScenario};
use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::placement::{EvictionPolicy, ModelArtifact, PlacementConfig, PlacementPolicy};
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::fleet::{
    FleetResult, FleetSimConfig, FleetSimulator, SatelliteSpec, TelemetryMode,
};
use leo_infer::sim::runner::{SimConfig, Simulator};
use leo_infer::sim::workload::{PoissonWorkload, SizeDist};
use leo_infer::sim::EventQueue;
use leo_infer::solver::instance::InstanceBuilder;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::proptest::Runner;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds};

fn profile() -> ModelProfile {
    ModelProfile::from_alphas("test-net", &[1000.0, 500.0, 250.0, 100.0, 20.0, 4.0]).unwrap()
}

fn template(rate_mbps: f64) -> InstanceBuilder {
    InstanceBuilder::new(profile())
        .rate(BitsPerSec::from_mbps(rate_mbps))
        .contact(Seconds::from_hours(8.0), Seconds::from_minutes(6.0))
}

fn mixed_trace(seed: u64) -> Vec<leo_infer::sim::workload::Request> {
    let mut rng = Pcg64::seeded(seed);
    PoissonWorkload::new(
        1.0 / 3000.0,
        SizeDist::LogUniform(Bytes::from_gb(0.2), Bytes::from_gb(2.0)),
    )
    .generate(Seconds::from_hours(24.0), &mut rng)
}

/// The acceptance criterion: an N=1 fleet run (unconstrained telemetry,
/// periodic contacts) reproduces the legacy single-satellite simulator
/// bit-identically — same records, same counters.
#[test]
fn n1_fleet_matches_the_legacy_simulator_bit_identically() {
    let trace = mixed_trace(7);
    let contact = PeriodicContact::new(Seconds::from_hours(8.0), Seconds::from_minutes(6.0));
    let horizon = Seconds::from_hours(100_000.0);

    let legacy_cfg = SimConfig {
        template: template(60.0),
        profiles: vec![profile()],
        contact,
        timing: false,
        horizon,
    };
    let legacy = Simulator::new(legacy_cfg)
        .run(&trace, &SolverRegistry::engine("ilpb").unwrap())
        .unwrap();

    let fleet_cfg = FleetSimConfig {
        template: template(60.0),
        profiles: vec![profile()],
        sats: vec![SatelliteSpec::new("sat-0", Box::new(contact))],
        routing: RoutingPolicy::RoundRobin,
        isl: None,
        isl_max_hops: 0,
        telemetry: TelemetryMode::Unconstrained,
        placement: PlacementConfig::default(),
        route_cache: true,
        timing: false,
        audit: true,
        trace: None,
        pipeline: None,
        horizon,
    };
    let fleet = FleetSimulator::new(fleet_cfg)
        .run(&trace, &SolverRegistry::engine("ilpb").unwrap())
        .unwrap();

    assert!(!legacy.metrics.records.is_empty());
    assert_eq!(
        legacy.metrics.records, fleet.metrics.records,
        "records must be bit-identical"
    );
    assert_eq!(legacy.metrics.rejected_admission, fleet.metrics.rejected_admission);
    assert_eq!(legacy.metrics.rejected_transmit, fleet.metrics.rejected_transmit);
    assert_eq!(legacy.metrics.unfinished, fleet.metrics.unfinished);
    assert_eq!(legacy.metrics.total_downlinked, fleet.metrics.total_downlinked);
    assert_eq!(
        legacy.state.energy_drawn.value(),
        fleet.states[0].energy_drawn.value()
    );
}

/// The placement acceptance criterion: an *active* placement layer —
/// `Everywhere` seeding with a huge (finite) budget, so every store is
/// exercised but every lookup hits — reproduces the passive default run
/// bit-identically. Warm stores mean zero miss penalties, zero fetch
/// events, and identical event ordering; only the hit counters may move.
#[test]
fn everywhere_with_room_for_everything_is_bit_identical() {
    let trace = mixed_trace(13);
    let horizon = Seconds::from_hours(100_000.0);
    let build = |placement: PlacementConfig| {
        let contact =
            PeriodicContact::new(Seconds::from_hours(8.0), Seconds::from_minutes(6.0));
        let phased =
            PeriodicContact::new(Seconds::from_hours(8.0), Seconds::from_minutes(6.0))
                .with_phase(Seconds(14_400.0));
        FleetSimConfig {
            template: template(60.0),
            profiles: vec![profile()],
            sats: vec![
                SatelliteSpec::new("sat-0", Box::new(contact)),
                SatelliteSpec::new("sat-1", Box::new(phased)),
            ],
            routing: RoutingPolicy::LeastLoaded,
            isl: None,
            isl_max_hops: 0,
            telemetry: TelemetryMode::Live,
            placement,
            route_cache: true,
            timing: false,
            audit: true,
            trace: None,
            pipeline: None,
            horizon,
        }
    };
    let passive = FleetSimulator::new(build(PlacementConfig::default()))
        .run(&trace, &SolverRegistry::engine("ilpb").unwrap())
        .unwrap();
    let active_cfg = PlacementConfig {
        policy: PlacementPolicy::Everywhere,
        eviction: EvictionPolicy::Lru,
        budget: Some(Bytes::from_gb(1.0e6)),
        artifacts: vec![ModelArtifact::from_profile(0, &profile(), Bytes::from_mb(200.0))],
    };
    assert!(!active_cfg.is_passive(), "a finite budget must arm the machinery");
    let active = FleetSimulator::new(build(active_cfg))
        .run(&trace, &SolverRegistry::engine("ilpb").unwrap())
        .unwrap();

    assert!(!passive.metrics.records.is_empty());
    assert_eq!(
        passive.metrics.records, active.metrics.records,
        "warm placement must be bit-identical to the passive default"
    );
    assert_eq!(passive.metrics.unfinished, active.metrics.unfinished);
    assert_eq!(passive.metrics.rejected_admission, active.metrics.rejected_admission);
    assert_eq!(passive.metrics.total_downlinked, active.metrics.total_downlinked);
    // the passive run never consults a store; the active one always hits
    assert_eq!(passive.metrics.artifact_hits, 0);
    assert_eq!(passive.metrics.artifact_misses, 0);
    assert!(active.metrics.artifact_hits > 0);
    assert_eq!(active.metrics.artifact_misses, 0);
    assert_eq!(active.metrics.evictions, 0);
    assert_eq!(active.metrics.weight_bytes_in, Bytes::ZERO);
}

/// Fleet runs are deterministic: identical configuration and trace produce
/// identical records and per-satellite breakdowns across fresh engines.
#[test]
fn fleet_runs_with_many_satellites_are_deterministic() {
    let run = || -> FleetResult {
        let mut scen = FleetScenario::walker_631();
        scen.horizon_hours = 48.0;
        scen.interarrival_s = 1200.0;
        scen.data_gb_lo = 0.2;
        scen.data_gb_hi = 4.0;
        let mut rng = Pcg64::seeded(11);
        let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
        let profile = ModelProfile::sampled(8, &mut rng);
        let engine = SolverRegistry::engine("ilpb").unwrap();
        FleetSimulator::new(scen.sim_config(profile).unwrap())
            .run(&trace, &engine)
            .unwrap()
    };
    let a = run();
    let b = run();
    assert!(a.metrics.completed() > 0, "scenario must serve something");
    assert_eq!(a.metrics.records, b.metrics.records);
    assert_eq!(a.metrics.rejected(), b.metrics.rejected());
    assert_eq!(a.metrics.unfinished, b.metrics.unfinished);
    for (sa, sb) in a.metrics.per_sat().iter().zip(b.metrics.per_sat()) {
        assert_eq!(sa.completed, sb.completed, "{}", sa.name);
        assert_eq!(sa.mean_latency(), sb.mean_latency(), "{}", sa.name);
    }
    // more than one satellite actually served traffic
    let active = a
        .metrics
        .per_sat()
        .iter()
        .filter(|s| s.completed > 0)
        .count();
    assert!(active > 1, "least-loaded routing must spread the work");
}

/// Property test: equal-time events pop in schedule order regardless of
/// how they interleave with other times (the DES's determinism anchor).
#[test]
fn equal_time_events_pop_in_schedule_order() {
    Runner::new("event queue tie-break", 300).run(|rng| {
        let mut q = EventQueue::new();
        let n = 3 + rng.index(50);
        for i in 0..n {
            // a tiny time alphabet forces heavy ties
            let t = rng.index(5) as f64;
            q.schedule(t, i);
        }
        let mut popped = Vec::with_capacity(n);
        while let Some(ev) = q.pop() {
            popped.push((ev.time, ev.event));
        }
        if popped.len() != n {
            return Err(format!("lost events: {} of {n}", popped.len()));
        }
        for w in popped.windows(2) {
            if w[0].0 > w[1].0 {
                return Err(format!("time order violated: {w:?}"));
            }
            if w[0].0 == w[1].0 && w[0].1 >= w[1].1 {
                return Err(format!("tie-break violated: {w:?}"));
            }
        }
        Ok(())
    });
}

/// Conservation across every outcome bucket, with batteries and live
/// telemetry in the loop.
#[test]
fn fleet_conserves_requests_across_all_buckets() {
    let mut scen = FleetScenario::walker_631();
    scen.horizon_hours = 48.0;
    scen.interarrival_s = 1800.0;
    scen.battery_capacity_j = 5.0e5;
    let mut rng = Pcg64::seeded(23);
    let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(10, &mut rng);
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let result = FleetSimulator::new(scen.sim_config(profile).unwrap())
        .run(&trace, &engine)
        .unwrap();
    let m = &result.metrics;
    assert_eq!(
        m.completed() + m.rejected() + m.unfinished,
        trace.len() as u64,
        "every request must land in exactly one bucket"
    );
    // the per-satellite slices tile the completed/attributed counts
    let sat_completed: u64 = m.per_sat().iter().map(|s| s.completed).sum();
    assert_eq!(sat_completed, m.completed());
    assert!(m.per_sat().iter().map(|s| s.rejected()).sum::<u64>() <= m.rejected());
}

/// Conservation holds with ISL relaying in the loop: every request lands
/// in exactly one bucket even when tensors hop between satellites, and
/// the relay telemetry stays internally consistent.
#[test]
fn relay_fleet_conserves_requests_across_all_buckets() {
    let mut scen = FleetScenario::walker_631();
    scen.horizon_hours = 48.0;
    scen.interarrival_s = 1200.0;
    scen.isl = leo_infer::link::isl::IslMode::Grid;
    scen.routing = "relay-aware".to_string();
    scen.battery_capacity_j = 5.0e5;
    let mut rng = Pcg64::seeded(29);
    let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(10, &mut rng);
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let result = FleetSimulator::new(scen.sim_config(profile).unwrap())
        .run(&trace, &engine)
        .unwrap();
    let m = &result.metrics;
    assert_eq!(
        m.completed() + m.rejected() + m.unfinished,
        trace.len() as u64,
        "every request must land in exactly one bucket with relays on"
    );
    let sat_completed: u64 = m.per_sat().iter().map(|s| s.completed).sum();
    assert_eq!(sat_completed, m.completed());
    // relay bookkeeping tiles: every handoff has exactly one sender and
    // one receiver
    let out: u64 = m.per_sat().iter().map(|s| s.relays_out).sum();
    let inn: u64 = m.per_sat().iter().map(|s| s.relays_in).sum();
    assert_eq!(out, m.relays);
    assert_eq!(inn, m.relays);
    let relayed: f64 = m.per_sat().iter().map(|s| s.relayed_bytes.value()).sum();
    assert!((relayed - m.relayed_bytes.value()).abs() < 1e-6);
    // records agree with the aggregate relay count
    let relayed_records = m.records.iter().filter(|r| r.relay.is_some()).count() as u64;
    assert!(
        relayed_records <= m.relays,
        "some relayed requests may be rejected/unfinished, never the reverse"
    );
}

/// RelayAware routing over an ISL grid is deterministic: identical
/// configuration and trace reproduce records, relay counts, and
/// per-satellite breakdowns exactly.
#[test]
fn relay_aware_routing_is_deterministic() {
    let run = || -> FleetResult {
        let mut scen = FleetScenario::walker_631();
        scen.horizon_hours = 48.0;
        scen.interarrival_s = 1500.0;
        scen.data_gb_lo = 0.2;
        scen.data_gb_hi = 2.0;
        scen.isl = leo_infer::link::isl::IslMode::Grid;
        scen.routing = "relay-aware".to_string();
        let mut rng = Pcg64::seeded(37);
        let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
        let profile = ModelProfile::sampled(8, &mut rng);
        let engine = SolverRegistry::engine("ilpb").unwrap();
        FleetSimulator::new(scen.sim_config(profile).unwrap())
            .run(&trace, &engine)
            .unwrap()
    };
    let a = run();
    let b = run();
    assert!(a.metrics.completed() > 0, "scenario must serve something");
    assert_eq!(a.metrics.records, b.metrics.records);
    assert_eq!(a.metrics.relays, b.metrics.relays);
    assert_eq!(a.metrics.relayed_bytes, b.metrics.relayed_bytes);
    assert_eq!(a.metrics.unfinished, b.metrics.unfinished);
    for (sa, sb) in a.metrics.per_sat().iter().zip(b.metrics.per_sat()) {
        assert_eq!(sa.completed, sb.completed, "{}", sa.name);
        assert_eq!(sa.relays_out, sb.relays_out, "{}", sa.name);
        assert_eq!(sa.relays_in, sb.relays_in, "{}", sa.name);
    }
}

/// Orbit-derived contact schedules drive the fleet end to end: a Walker
/// 6/3/1 over Beijing serves captures through geometry-computed passes.
#[test]
fn orbit_derived_fleet_serves_captures_end_to_end() {
    let mut scen = FleetScenario::walker_631();
    scen.contact_source = ContactSource::Orbit;
    scen.horizon_hours = 24.0;
    scen.interarrival_s = 3600.0;
    scen.data_gb_lo = 0.05;
    scen.data_gb_hi = 0.5;
    let mut rng = Pcg64::seeded(31);
    let trace = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(10, &mut rng);
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let result = FleetSimulator::new(scen.sim_config(profile).unwrap())
        .run(&trace, &engine)
        .unwrap();
    let m = &result.metrics;
    assert!(
        m.completed() > 0,
        "a day of small captures must produce completions through real passes"
    );
    assert_eq!(m.completed() + m.rejected() + m.unfinished, trace.len() as u64);
    // downlinked work must have used the schedule, not the periodic preset
    assert_eq!(m.per_sat().len(), 6);
}
