//! Property tests over the `solver::engine` public API: exact solvers
//! agree through the engine, the decision cache replays bit-identically
//! and skips ≥90% of solves on repeated workloads, and telemetry
//! tightening never produces an infeasible or suboptimal-within-allowed
//! decision.

use leo_infer::dnn::profile::ModelProfile;
use leo_infer::solver::instance::{Instance, InstanceBuilder};
use leo_infer::solver::{
    Exhaustive, OffloadPolicy, SolveRequest, SolverEngine, SolverRegistry, Telemetry,
};
use leo_infer::util::proptest::Runner;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds, Watts};

fn random_instance(rng: &mut Pcg64) -> Instance {
    let k = 1 + rng.index(24);
    InstanceBuilder::new(ModelProfile::sampled(k, rng))
        .data(Bytes::from_gb(rng.uniform(0.5, 800.0)))
        .beta_s_per_kb(rng.uniform(0.01, 0.03))
        .gamma_s_per_kb(rng.uniform(0.0001, 0.001))
        .rate(BitsPerSec::from_mbps(rng.uniform(10.0, 100.0)))
        .gpu(
            rng.uniform(50.0, 5000.0),
            Watts(rng.uniform(1.0, 10.0)),
            Watts(rng.uniform(0.05, 1.0)),
            Watts(rng.uniform(0.01, 0.2)),
        )
        .p_off(Watts(rng.uniform(0.5, 10.0)))
        .weights(0.5, 0.5)
        .build()
        .unwrap()
}

#[test]
fn engine_wrapped_exact_solvers_agree_on_optimal_z() {
    let engines: Vec<SolverEngine> = ["ilpb", "dp", "exhaustive"]
        .iter()
        .map(|n| SolverRegistry::engine(n).unwrap())
        .collect();
    Runner::new("engine(ilpb) == engine(dp) == engine(exhaustive)", 300).run(|rng| {
        let inst = random_instance(rng);
        let mut answers = Vec::new();
        for e in &engines {
            let out = e.solve(&SolveRequest::new(inst.clone()));
            answers.push((e.policy_name(), out.decision.z, out.decision.split));
        }
        let (_, z0, s0) = answers[0];
        for &(name, z, s) in &answers[1..] {
            if (z - z0).abs() > 1e-9 {
                return Err(format!("{name}: z {z} vs {z0} (splits {s} vs {s0})"));
            }
        }
        Ok(())
    });
}

#[test]
fn cache_replays_bit_identical_decisions() {
    let engine = SolverRegistry::engine("ilpb").unwrap();
    Runner::new("cache replay is bit-identical", 100).run(|rng| {
        let inst = random_instance(rng);
        let req = SolveRequest::new(inst);
        let first = engine.solve(&req);
        let replay = engine.solve(&req);
        if !replay.cached {
            // LRU capacity can evict under many distinct instances, but
            // an immediate replay must always hit
            return Err("immediate replay missed the cache".into());
        }
        // bit-identical: full structural equality including the h vector
        // and every cost component
        (replay.decision == first.decision)
            .then_some(())
            .ok_or_else(|| format!("replayed {:?} != {:?}", replay.decision, first.decision))
    });
}

#[test]
fn repeated_workload_skips_over_ninety_percent_of_solves() {
    // the acceptance workload: heavy traffic cycling a small set of
    // request shapes, exactly what a batcher emits at steady state
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let mut rng = Pcg64::seeded(0xCACE);
    let shapes: Vec<Instance> = (0..25).map(|_| random_instance(&mut rng)).collect();
    let fresh: Vec<f64> = shapes
        .iter()
        .map(|i| Exhaustive.decide(i).z)
        .collect();
    let total = 1000usize;
    for i in 0..total {
        let inst = &shapes[i % shapes.len()];
        let out = engine.solve_parts(inst, &Telemetry::unconstrained());
        assert!(
            (out.decision.z - fresh[i % shapes.len()]).abs() < 1e-9,
            "request {i}: cached path changed the optimum"
        );
    }
    let stats = engine.stats();
    assert_eq!(stats.requests, total as u64);
    assert_eq!(stats.solves, shapes.len() as u64);
    assert!(
        stats.hit_rate() >= 0.9,
        "must skip ≥90% of solves on a repeated workload, got {:.1}%",
        stats.hit_rate() * 100.0
    );
}

#[test]
fn tightened_decisions_respect_telemetry_and_stay_feasible() {
    let engine = SolverRegistry::engine("ilpb").unwrap();
    Runner::new("telemetry tightening is sound", 200).run(|rng| {
        let inst = random_instance(rng);
        let k = inst.depth();
        let window = Seconds(rng.uniform(1.0, 5000.0));
        let tel = Telemetry::unconstrained().with_contact_remaining(window);
        let out = engine.solve_parts(&inst, &tel);
        let s = out.decision.split;
        if s > k {
            return Err(format!("split {s} out of range"));
        }
        // unless the engine had to relax (only possible when even s = K
        // is excluded, which the contact rule never does), a transmitting
        // split must fit the window
        if s < k {
            let tx = inst.downlink.transmission_time(inst.wire_bytes(s));
            if tx.value() > window.value() * (1.0 + 1e-6) {
                return Err(format!(
                    "split {s} transmits for {} s into a {} s window",
                    tx.value(),
                    window.value()
                ));
            }
        }
        // and the result can never beat the unconstrained optimum
        let best = Exhaustive.decide(&inst);
        (out.decision.z >= best.z - 1e-9)
            .then_some(())
            .ok_or_else(|| "tightened decision beat the global optimum".into())
    });
}

#[test]
fn batch_solving_amortizes_and_matches_serial_solving() {
    let mut rng = Pcg64::seeded(0xBA7C);
    let engine = SolverRegistry::engine("dp").unwrap();
    let serial = SolverRegistry::engine("dp").unwrap();
    let shapes: Vec<Instance> = (0..4).map(|_| random_instance(&mut rng)).collect();
    let reqs: Vec<SolveRequest> = (0..64)
        .map(|i| SolveRequest::new(shapes[i % shapes.len()].clone()))
        .collect();
    let outs = engine.solve_batch(&reqs);
    assert_eq!(outs.len(), reqs.len());
    assert_eq!(engine.stats().solves, shapes.len() as u64);
    for (req, out) in reqs.iter().zip(&outs) {
        let expect = serial.solve(req);
        assert_eq!(out.decision, expect.decision, "batch differs from serial");
    }
}
