//! Cross-module property tests on the solver: the invariants that make the
//! paper's optimization sound, checked over randomized scenarios and real
//! zoo-model profiles.

use leo_infer::config::Scenario;
use leo_infer::dnn::{models, profile::ModelProfile};
use leo_infer::solver::instance::{Instance, InstanceBuilder};
use leo_infer::solver::{Arg, Ars, DpSolver, Exhaustive, Greedy, Ilpb, OffloadPolicy};
use leo_infer::util::proptest::Runner;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds, Watts};

fn random_instance(rng: &mut Pcg64) -> Instance {
    let k = 1 + rng.index(32);
    InstanceBuilder::new(ModelProfile::sampled(k, rng))
        .data(Bytes::from_gb(rng.uniform(0.1, 1000.0)))
        .beta_s_per_kb(rng.uniform(0.01, 0.03))
        .gamma_s_per_kb(rng.uniform(0.0001, 0.001))
        .rate(BitsPerSec::from_mbps(rng.uniform(10.0, 100.0)))
        .contact(
            Seconds::from_hours(rng.uniform(1.0, 24.0)),
            Seconds::from_minutes(rng.uniform(1.0, 10.0)),
        )
        .gpu(
            rng.uniform(10.0, 10000.0),
            Watts(rng.uniform(1.0, 10.0)),
            Watts(rng.uniform(0.01, 1.0)),
            Watts(rng.uniform(0.001, 0.2)),
        )
        .p_off(Watts(rng.uniform(0.5, 12.0)))
        .weights(0.5, 0.5)
        .build()
        .unwrap()
}

#[test]
fn all_exact_solvers_agree_everywhere() {
    Runner::new("ilpb == dp == exhaustive", 400).run(|rng| {
        let inst = random_instance(rng);
        let a = Ilpb::default().decide(&inst).z;
        let b = DpSolver.decide(&inst).z;
        let c = Exhaustive.decide(&inst).z;
        ((a - b).abs() < 1e-9 && (b - c).abs() < 1e-9)
            .then_some(())
            .ok_or_else(|| format!("ilpb {a} dp {b} exhaustive {c}"))
    });
}

#[test]
fn optimum_is_global_over_feasible_set() {
    Runner::new("no feasible h beats ILPB", 200).run(|rng| {
        let inst = random_instance(rng);
        let obj = inst.objective();
        let best = Ilpb::default().decide(&inst).z;
        for s in 0..=inst.depth() {
            if inst.z_of_split(s, &obj) < best - 1e-9 {
                return Err(format!("split {s} beats the optimum"));
            }
        }
        Ok(())
    });
}

#[test]
fn pure_latency_scale_invariance() {
    Runner::new("λ=1 split invariant under time rescale", 100).run(|rng| {
        let k = 2 + rng.index(12);
        let profile = ModelProfile::sampled(k, rng);
        let d = Bytes::from_gb(rng.uniform(1.0, 100.0));
        let mk = |c: f64| {
            InstanceBuilder::new(profile.clone())
                .data(d)
                .beta_s_per_kb(0.02 * c)
                .gamma_s_per_kb(0.0005 * c)
                .gamma_max_s_per_kb(0.001 * c) // the cap is time-like too
                .rate(BitsPerSec::from_mbps(55.0 / c))
                .contact(
                    Seconds::from_hours(8.0 * c),
                    Seconds::from_minutes(6.0 * c),
                )
                .ground_rate(BitsPerSec::from_mbps(10_000.0 / c))
                .weights(0.0, 1.0)
                .build()
                .unwrap()
        };
        let c = rng.uniform(2.0, 10.0);
        let s0 = Ilpb::default().decide(&mk(1.0)).split;
        let s1 = Ilpb::default().decide(&mk(c)).split;
        (s0 == s1)
            .then_some(())
            .ok_or_else(|| format!("split moved {s0} → {s1} under c={c}"))
    });
}

#[test]
fn latency_monotone_in_data_size_for_every_policy() {
    Runner::new("T(D) monotone", 100).run(|rng| {
        let k = 2 + rng.index(10);
        let profile = ModelProfile::sampled(k, rng);
        let policies: [&dyn OffloadPolicy; 4] =
            [&Ilpb::default(), &Arg, &Ars, &Greedy];
        let mut prev = vec![0.0; policies.len()];
        for gb in [1.0, 10.0, 100.0, 1000.0] {
            let inst = InstanceBuilder::new(profile.clone())
                .data(Bytes::from_gb(gb))
                .build()
                .unwrap();
            for (i, p) in policies.iter().enumerate() {
                let t = p.decide(&inst).costs.latency.value();
                if t + 1e-9 < prev[i] {
                    return Err(format!(
                        "{} latency fell with data size at {gb} GB",
                        p.name()
                    ));
                }
                prev[i] = t;
            }
        }
        Ok(())
    });
}

#[test]
fn ilpb_latency_monotone_in_rate_under_pure_latency_objective() {
    // under λ=1, ILPB latency = min_s T(s), and every T(s) is
    // non-increasing in R ⇒ the min is non-increasing. (Under mixed
    // weights the chosen split can legitimately trade latency for energy
    // as the rate changes, so only the average falls — see Fig 3.)
    Runner::new("ILPB T(R) non-increasing at λ=1", 100).run(|rng| {
        let k = 2 + rng.index(10);
        let profile = ModelProfile::sampled(k, rng);
        let mut prev = f64::INFINITY;
        for mbps in [10.0, 25.0, 50.0, 75.0, 100.0] {
            let inst = InstanceBuilder::new(profile.clone())
                .rate(BitsPerSec::from_mbps(mbps))
                .weights(0.0, 1.0)
                .build()
                .unwrap();
            let t = Ilpb::default().decide(&inst).costs.latency.value();
            if t > prev + 1e-9 {
                return Err(format!("latency rose with rate at {mbps} Mbps"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn zoo_models_solve_cleanly() {
    // every real architecture yields a valid instance and consistent
    // decisions at several data scales
    for net in models::zoo() {
        let profile = ModelProfile::from_network(&net).unwrap();
        for gb in [0.1, 10.0, 1000.0] {
            let inst = Scenario::tiansuan()
                .instance_builder(profile.clone())
                .data(Bytes::from_gb(gb))
                .build()
                .unwrap();
            let d = Ilpb::default().decide(&inst);
            let oracle = Exhaustive.decide(&inst);
            assert!(
                (d.z - oracle.z).abs() < 1e-9,
                "{} at {gb} GB: {} vs {}",
                net.name,
                d.z,
                oracle.z
            );
            assert!(inst.feasible(&d.h));
        }
    }
}

#[test]
fn weights_shift_the_split_monotonically_toward_energy_saving() {
    // as μ grows the chosen energy must not increase (the fig-4 property,
    // here asserted per-instance rather than on averages)
    Runner::new("energy(μ) non-increasing", 150).run(|rng| {
        let k = 2 + rng.index(12);
        let profile = ModelProfile::sampled(k, rng);
        let d = Bytes::from_gb(rng.uniform(1.0, 500.0));
        let mut prev_energy = f64::INFINITY;
        for mu in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let inst = InstanceBuilder::new(profile.clone())
                .data(d)
                .weights(mu, 1.0 - mu)
                .build()
                .unwrap();
            let e = Ilpb::default().decide(&inst).costs.energy.value();
            if e > prev_energy + 1e-6 {
                return Err(format!("energy rose as μ grew to {mu}: {e} > {prev_energy}"));
            }
            prev_energy = e;
        }
        Ok(())
    });
}

#[test]
fn greedy_never_beats_exact_and_arg_ars_bracket() {
    Runner::new("ordering sanity", 200).run(|rng| {
        let inst = random_instance(rng);
        let z_best = Ilpb::default().decide(&inst).z;
        for p in [&Greedy as &dyn OffloadPolicy, &Arg, &Ars] {
            if p.decide(&inst).z < z_best - 1e-9 {
                return Err(format!("{} beat the exact optimum", p.name()));
            }
        }
        Ok(())
    });
}
