//! The runtime invariant audit: seeded violations prove each check
//! fires, the disabled audit is inert, and a contentious audit-enabled
//! fleet run (batteries + cold stores + evictions) finishes clean —
//! i.e. the checks catch corrupt state without false-positiving on a
//! legitimate scenario.

use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::energy::battery::Battery;
use leo_infer::energy::solar::SolarPanel;
use leo_infer::placement::{
    EvictionPolicy, ModelArtifact, PlacementConfig, PlacementPolicy,
};
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::fleet::{FleetSimConfig, FleetSimulator, SatelliteSpec, TelemetryMode};
use leo_infer::sim::invariants::{
    self, battery_in_bounds, eviction_respects_pins, pops_monotone, requests_conserved,
    store_within_budget, Audit, Violation,
};
use leo_infer::sim::workload::Request;
use leo_infer::solver::instance::InstanceBuilder;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::units::{BitsPerSec, Bytes, Joules, Seconds};

// ---------------------------------------------------------------- seeded
// violations: every predicate must reject its namesake corruption

#[test]
fn negative_battery_draw_fires() {
    let v = battery_in_bounds(3, -5.0, 100.0).unwrap_err();
    assert!(matches!(v, Violation::Battery { sat: 3, .. }));
    assert!(battery_in_bounds(0, 105.0, 100.0).is_err(), "overcharge");
    assert!(battery_in_bounds(0, f64::NAN, 100.0).is_err(), "NaN charge");
    assert!(battery_in_bounds(0, 0.0, 100.0).is_ok());
    assert!(battery_in_bounds(0, 100.0, 100.0).is_ok());
}

#[test]
fn out_of_order_event_injection_fires() {
    let v = pops_monotone(10.0, 5.0).unwrap_err();
    assert!(matches!(v, Violation::EventOrder { .. }));
    assert!(pops_monotone(5.0, f64::NAN).is_err(), "NaN pop time");
    assert!(pops_monotone(5.0, 5.0).is_ok(), "equal times are legal");
    assert!(pops_monotone(5.0, 6.0).is_ok());
}

#[test]
fn over_budget_store_insert_fires() {
    let v = store_within_budget(1, 200.0e6, Some(100.0e6)).unwrap_err();
    assert!(matches!(v, Violation::StoreBudget { sat: 1, .. }));
    assert!(store_within_budget(1, 200.0e6, None).is_ok(), "unbudgeted");
    assert!(store_within_budget(1, 100.0e6, Some(100.0e6)).is_ok());
    assert!(store_within_budget(1, f64::NAN, Some(100.0e6)).is_err());
}

#[test]
fn evicting_a_pinned_model_fires() {
    // model 1 has 3 queued requests: evicting it must be caught
    let v = eviction_respects_pins(2, &[1], &[0, 3]).unwrap_err();
    assert_eq!(
        v,
        Violation::PinnedEviction {
            sat: 2,
            model: 1,
            inflight: 3
        }
    );
    assert!(eviction_respects_pins(2, &[0], &[0, 3]).is_ok());
    assert!(eviction_respects_pins(2, &[], &[9, 9]).is_ok(), "no victims");
}

#[test]
fn vanished_request_fires() {
    let v = requests_conserved(10, 4, 2, 3).unwrap_err();
    assert!(matches!(v, Violation::Conservation { arrived: 10, .. }));
    assert!(requests_conserved(10, 4, 3, 3).is_ok());
    assert!(requests_conserved(0, 0, 0, 0).is_ok());
    assert!(requests_conserved(5, 3, 3, 0).is_err(), "double-counted");
}

// ------------------------------------------------------------ the Audit
// wrapper: enabled it panics, disabled it is inert

#[test]
#[should_panic(expected = "sim invariant violated")]
fn enabled_audit_panics_on_backwards_pop() {
    let mut audit = Audit::new(true);
    audit.on_pop(10.0);
    audit.on_pop(3.0);
}

#[test]
#[should_panic(expected = "sim invariant violated")]
fn enabled_audit_panics_on_pinned_eviction() {
    let audit = Audit::new(true);
    audit.on_eviction(0, &[2], &[0, 0, 5]);
}

#[test]
fn disabled_audit_never_panics() {
    let mut audit = Audit::new(false);
    assert!(!audit.enabled());
    audit.on_pop(10.0);
    audit.on_pop(3.0); // backwards: ignored
    audit.on_eviction(0, &[2], &[0, 0, 5]); // pinned: ignored
}

#[test]
fn violations_render_debuggable_messages() {
    let v = invariants::battery_in_bounds(7, -1.5, 80.0).unwrap_err();
    let msg = v.to_string();
    assert!(msg.contains("sat 7"), "message was: {msg}");
    assert!(msg.contains("-1.5"), "message was: {msg}");
}

// ------------------------------------------------------- end-to-end: a
// contentious audited run must finish without tripping any check

fn profile(name: &str) -> ModelProfile {
    ModelProfile::from_alphas(name, &[1000.0, 500.0, 250.0, 100.0, 20.0, 4.0]).unwrap()
}

#[test]
fn audited_fleet_run_with_batteries_and_evictions_is_clean() {
    let profiles = vec![profile("net-a"), profile("net-b")];
    // budget holds exactly one 200 MB model: alternating models force
    // fetches, evictions, and pin checks on every satellite
    let placement = PlacementConfig {
        policy: PlacementPolicy::Demand,
        eviction: EvictionPolicy::Lru,
        budget: Some(Bytes::from_mb(250.0)),
        artifacts: vec![
            ModelArtifact::from_profile(0, &profiles[0], Bytes::from_mb(200.0)),
            ModelArtifact::from_profile(1, &profiles[1], Bytes::from_mb(180.0)),
        ],
    };
    let template = InstanceBuilder::new(profiles[0].clone())
        .rate(BitsPerSec::from_mbps(100.0))
        .contact(Seconds::from_hours(8.0), Seconds::from_minutes(6.0));
    let sats = (0..2)
        .map(|i| {
            let contact = PeriodicContact::new(
                Seconds::from_hours(8.0),
                Seconds::from_minutes(6.0),
            )
            .with_phase(Seconds(i as f64 * 3600.0));
            SatelliteSpec::new(&format!("sat-{i}"), Box::new(contact)).with_battery(
                Battery::new(Joules(5.0e5), 0.1),
                SolarPanel::new(1.0, 0.3, 0.8),
                0.6,
            )
        })
        .collect();
    let cfg = FleetSimConfig {
        template,
        profiles,
        sats,
        routing: RoutingPolicy::LeastLoaded,
        isl: None,
        isl_max_hops: 0,
        telemetry: TelemetryMode::Live,
        placement,
        route_cache: true,
        timing: false,
        audit: true,
        trace: None,
        pipeline: None,
        horizon: Seconds::from_hours(100_000.0),
    };
    let trace: Vec<Request> = (0..12)
        .map(|i| Request {
            id: i,
            arrival: Seconds(600.0 * i as f64),
            data: Bytes::from_mb(40.0),
            model: (i % 2) as usize,
            class: 0,
        })
        .collect();
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let result = FleetSimulator::new(cfg).run(&trace, &engine).unwrap();
    // conservation holds (the audit already enforced it; assert anyway
    // so the test documents the property, not just the absence of panic)
    let m = &result.metrics;
    assert_eq!(m.completed() + m.rejected() + m.unfinished, 12);
    assert!(
        m.artifact_misses > 0,
        "alternating models over a one-model budget must miss"
    );
}
