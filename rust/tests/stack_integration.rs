//! Whole-stack integration: orbit → link → energy → solver → sim, plus the
//! AOT-artifact path when artifacts are present.

use leo_infer::config::Scenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::orbit::contact::ContactSchedule;
use leo_infer::orbit::geometry::GroundStation;
use leo_infer::orbit::propagator::CircularOrbit;
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::runner::{SimConfig, Simulator};
use leo_infer::sim::workload::{PoissonWorkload, SizeDist};
use leo_infer::solver::{Ilpb, OffloadPolicy, SolverRegistry};
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{Bytes, Seconds};

/// Orbit-derived contact parameters flow into the solver and produce a
/// decision consistent with the paper's fixed-parameter preset.
#[test]
fn orbit_derived_contacts_feed_the_solver() {
    let orbit = CircularOrbit::new(500.0, 97.4, 30.0, 0.0);
    let gs = GroundStation::new("beijing", 39.9, 116.4).with_elevation_mask(10.0);
    let sched = ContactSchedule::compute(&orbit, &gs, 7.0 * 86_400.0, 30.0);
    assert!(sched.windows.len() >= 7, "a week should have many passes");
    let t_con = sched.mean_duration();
    let t_cyc = sched.mean_period().unwrap();
    // physical sanity: minutes-long passes, hours-long gaps
    assert!((1.0..=12.0).contains(&t_con.minutes()), "{}", t_con.minutes());
    assert!((1.0..=25.0).contains(&t_cyc.hours()), "{}", t_cyc.hours());

    let mut scen = Scenario::tiansuan();
    scen.t_cyc_hours = t_cyc.hours();
    scen.t_con_minutes = t_con.minutes();
    let mut rng = Pcg64::seeded(5);
    let profile = ModelProfile::sampled(10, &mut rng);
    let inst = scen.instance_builder(profile).build().unwrap();
    let d = Ilpb::default().decide(&inst);
    assert!(d.z.is_finite());
    assert!(inst.feasible(&d.h));
}

/// The full scenario sim conserves requests and orders policies sanely
/// under a heavy queueing workload.
#[test]
fn week_long_simulation_conserves_and_orders() {
    let scen = Scenario::tiansuan().with_rate_mbps(20.0);
    let mut rng = Pcg64::seeded(6);
    let profile = ModelProfile::sampled(10, &mut rng);
    // one week of captures; the *sim* horizon is far larger so the backlog
    // drains completely (the horizon is enforced now — late events would
    // otherwise be cut and counted unfinished)
    let capture_window = Seconds::from_hours(168.0);
    let horizon = Seconds::from_hours(200_000.0);
    let trace = PoissonWorkload::new(
        1.0 / 3600.0,
        SizeDist::LogUniform(Bytes::from_gb(1.0), Bytes::from_gb(50.0)),
    )
    .generate(capture_window, &mut rng);

    let mut by_policy = Vec::new();
    for name in ["ilpb", "arg", "ars"] {
        let engine = SolverRegistry::engine(name).unwrap();
        let cfg = SimConfig {
            template: scen.instance_builder(profile.clone()),
            profiles: vec![profile.clone()],
            contact: PeriodicContact::new(
                Seconds::from_hours(scen.t_cyc_hours),
                Seconds::from_minutes(scen.t_con_minutes),
            ),
            timing: false,
            horizon,
        };
        let result = Simulator::new(cfg).run(&trace, &engine).unwrap();
        assert_eq!(
            result.metrics.completed() as usize + result.metrics.rejected() as usize,
            trace.len(),
            "{}: conservation",
            engine.policy_name()
        );
        assert_eq!(
            result.metrics.unfinished, 0,
            "{}: a generous horizon must drain the backlog",
            engine.policy_name()
        );
        by_policy.push((engine.policy_name(), result));
    }
    // ILPB's mean Z-weighted qualities: never above both baselines on both
    // axes simultaneously (weaker but assignment-free check: ILPB's
    // latency ≤ ARS's and energy ≤ ARS's; downlink ≤ ARG's)
    let get = |n: &str| by_policy.iter().find(|(name, _)| *name == n).unwrap();
    let (_, ilpb) = get("ILPB");
    let (_, arg) = get("ARG");
    let (_, ars) = get("ARS");
    assert!(ilpb.metrics.total_downlinked <= arg.metrics.total_downlinked);
    assert!(ilpb.metrics.mean_latency() <= ars.metrics.mean_latency());
    assert!(ilpb.state.energy_drawn.value() <= ars.state.energy_drawn.value());
}

/// Measured (AOT manifest) and analytic (layer algebra) RSNet profiles
/// produce the SAME offloading decision across a scenario sweep — the
/// lockstep guarantee the runtime depends on.
#[test]
fn measured_and_analytic_profiles_agree_on_decisions() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = leo_infer::runtime::artifacts::Manifest::load(dir).unwrap();
    let measured = manifest.measured_profile(1).unwrap();
    let analytic =
        ModelProfile::from_network(&leo_infer::dnn::models::rsnet9()).unwrap();
    for gb in [0.1, 1.0, 10.0, 100.0, 1000.0] {
        for rate in [10.0, 55.0, 100.0] {
            let scen = Scenario::tiansuan().with_rate_mbps(rate);
            let i1 = scen
                .instance_builder(measured.clone())
                .data(Bytes::from_gb(gb))
                .build()
                .unwrap();
            let i2 = scen
                .instance_builder(analytic.clone())
                .data(Bytes::from_gb(gb))
                .build()
                .unwrap();
            let d1 = Ilpb::default().decide(&i1);
            let d2 = Ilpb::default().decide(&i2);
            assert_eq!(
                d1.split, d2.split,
                "profiles disagree at D={gb} GB, R={rate} Mbps"
            );
            assert!((d1.z - d2.z).abs() < 1e-9);
        }
    }
}

/// Figures pipeline smoke at low seed count (full runs live in benches).
#[test]
fn figures_pipeline_smoke() {
    let f2 = leo_infer::figures::fig2(3);
    let f3 = leo_infer::figures::fig3(3);
    let f4 = leo_infer::figures::fig4(3);
    assert_eq!(f2.len(), 10);
    assert_eq!(f3.len(), 10);
    assert_eq!(f4.len(), 5);
    let (e, t) = leo_infer::figures::headline_ratio(&f2);
    assert!(e > 0.0 && e < 1.0);
    assert!(t > 0.0 && t < 1.0);
}

/// Scenario JSON round-trips through the solver identically.
#[test]
fn scenario_file_reproduces_decisions() {
    let scen = Scenario::transmission_dominant()
        .with_data_gb(42.0)
        .with_weights(0.3, 0.7);
    let path = std::env::temp_dir().join("leo_infer_stack_scenario.json");
    scen.save(path.to_str().unwrap()).unwrap();
    let loaded = Scenario::load(path.to_str().unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);

    let mut rng = Pcg64::seeded(77);
    let profile = ModelProfile::sampled(12, &mut rng);
    let d1 = Ilpb::default().decide(&scen.instance_builder(profile.clone()).build().unwrap());
    let d2 = Ilpb::default().decide(&loaded.instance_builder(profile).build().unwrap());
    assert_eq!(d1.split, d2.split);
    assert_eq!(d1.z, d2.z);
}
