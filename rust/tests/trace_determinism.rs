//! Trace determinism: the `obs` acceptance criteria as executable tests.
//!
//! * Tracing **off** is the default and must be free: a traced run's
//!   metrics are bit-identical to an untraced run of the same scenario
//!   (the recorder observes, never feeds back).
//! * Tracing **on** is deterministic: the same seed and scenario produce
//!   byte-identical JSONL (and Chrome JSON) across fresh runs, and the
//!   sweep's worst-P99 cell — the one `--worst-cell-trace` drills into —
//!   is the same at any thread count, with a byte-identical trace.
//! * Both exporters emit what `leo-infer trace-validate` accepts.

use leo_infer::config::FleetScenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::exp::{run_cell_traced, run_sweep, Axes, SweepSpec};
use leo_infer::obs::{validate, SpanPhase, TraceConfig, TraceEvent, TraceFormat};
use leo_infer::sim::fleet::{FleetResult, FleetSimulator};
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::Seconds;

/// A small Walker fleet with relays and gauge sampling — every event
/// kind the recorder knows shows up in its trace.
fn scenario() -> FleetScenario {
    let mut scen = FleetScenario::walker_631();
    scen.horizon_hours = 24.0;
    scen.interarrival_s = 900.0;
    scen.data_gb_lo = 0.2;
    scen.data_gb_hi = 2.0;
    scen.isl = leo_infer::link::isl::IslMode::Ring;
    scen.routing = "relay-aware".to_string();
    scen.trace = true;
    scen.trace_sample_every_s = 3600.0;
    scen
}

fn run(scen: &FleetScenario, seed: u64) -> FleetResult {
    let mut rng = Pcg64::seeded(seed);
    let workload = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(8, &mut rng);
    let engine = SolverRegistry::engine("ilpb").unwrap();
    FleetSimulator::new(scen.sim_config(profile).unwrap())
        .run(&workload, &engine)
        .unwrap()
}

#[test]
fn same_seed_traces_are_byte_identical() {
    let scen = scenario();
    let a = run(&scen, 17);
    let b = run(&scen, 17);
    let ta = a.trace.expect("tracing armed");
    let tb = b.trace.expect("tracing armed");
    assert!(!ta.events.is_empty(), "the run must record something");
    assert_eq!(ta.to_jsonl(), tb.to_jsonl(), "JSONL must match byte for byte");
    assert_eq!(
        ta.to_chrome().to_string_pretty(),
        tb.to_chrome().to_string_pretty(),
        "Chrome JSON must match byte for byte"
    );
}

#[test]
fn tracing_never_perturbs_the_simulation() {
    let traced_scen = scenario();
    let mut untraced_scen = scenario();
    untraced_scen.trace = false;
    untraced_scen.trace_sample_every_s = 0.0;
    let traced = run(&traced_scen, 17);
    let untraced = run(&untraced_scen, 17);
    assert!(untraced.trace.is_none(), "tracing off must record nothing");
    assert!(!untraced.metrics.records.is_empty());
    assert_eq!(
        traced.metrics.records, untraced.metrics.records,
        "records must be bit-identical with tracing on"
    );
    assert_eq!(traced.metrics.rejected(), untraced.metrics.rejected());
    assert_eq!(traced.metrics.unfinished, untraced.metrics.unfinished);
    assert_eq!(traced.metrics.relays, untraced.metrics.relays);
    assert_eq!(traced.metrics.total_downlinked, untraced.metrics.total_downlinked);
    for (a, b) in traced
        .metrics
        .per_sat()
        .iter()
        .zip(untraced.metrics.per_sat())
    {
        assert_eq!(a.completed, b.completed, "{}", a.name);
        assert_eq!(a.mean_latency(), b.mean_latency(), "{}", a.name);
    }
}

#[test]
fn trace_cross_checks_the_metrics() {
    let scen = scenario();
    let result = run(&scen, 17);
    let m = &result.metrics;
    let trace = result.trace.expect("tracing armed");
    // one terminal mark per terminal outcome
    let done = trace.count(|e| matches!(e, TraceEvent::Done { .. }));
    let rejects = trace.count(|e| matches!(e, TraceEvent::Reject { .. }));
    let unfinished = trace.count(|e| matches!(e, TraceEvent::Unfinished { .. }));
    assert_eq!(done as u64, m.completed());
    assert_eq!(rejects as u64, m.rejected());
    assert_eq!(unfinished as u64, m.unfinished);
    // the name table indexes every satellite the events mention
    assert_eq!(trace.sats.len(), m.per_sat().len());
    // gauge ticks: every satellite sampled at every cadence multiple
    let gauges = trace.count(|e| matches!(e, TraceEvent::Gauge { .. }));
    assert!(gauges > 0 && gauges % trace.sats.len() == 0);
    // spans are well-formed
    for ev in &trace.events {
        if let TraceEvent::Span {
            queued, start, end, ..
        } = ev
        {
            assert!(queued <= start && start <= end, "malformed span {ev:?}");
        }
    }
}

#[test]
fn both_exports_pass_the_validator() {
    let scen = scenario();
    let trace = run(&scen, 17).trace.expect("tracing armed");
    let (fmt, summary) = validate(&trace.to_jsonl()).expect("jsonl must validate");
    assert_eq!(fmt, TraceFormat::Jsonl);
    assert_eq!(summary.events, trace.events.len());
    assert!(summary.spans > 0 && summary.marks > 0 && summary.gauges > 0);
    let (fmt, chrome) = validate(&trace.to_chrome().to_string_pretty())
        .expect("chrome must validate");
    assert_eq!(fmt, TraceFormat::Chrome);
    assert!(chrome.events > 0);
}

#[test]
fn pipeline_stage_spans_cross_check_the_metrics() {
    // Arm multi-node pipelines on the traced scenario: the trace stays
    // byte-deterministic, and every pipeline stage the metrics count
    // appears as exactly one `stage` span (both are recorded at the same
    // stage-start event, so the equality holds even for requests still in
    // flight at the horizon).
    let stage_spans = |t: &leo_infer::obs::Trace| {
        t.count(|e| matches!(e, TraceEvent::Span { phase: SpanPhase::Stage, .. }))
    };
    let mut scen = scenario();
    scen.pipeline = true;
    scen.pipeline_max_nodes = 3;
    let a = run(&scen, 17);
    let b = run(&scen, 17);
    let ta = a.trace.expect("tracing armed");
    let tb = b.trace.expect("tracing armed");
    assert_eq!(ta.to_jsonl(), tb.to_jsonl(), "pipelined JSONL must match byte for byte");
    let staged: u64 = a.metrics.per_sat().iter().map(|s| s.pipeline_stages).sum();
    assert_eq!(stage_spans(&ta) as u64, staged, "one stage span per counted stage");
    // completed multi-stage records stay within the configured chain
    // bound and keep a coherent timeline
    for r in a.metrics.records.iter().filter(|r| r.stages > 1) {
        assert!(r.stages <= scen.pipeline_max_nodes, "record exceeds the node cap");
        assert!(r.completed >= r.arrival, "completion precedes arrival");
    }
    // pipelines off (the baseline scenario) must emit no stage spans
    let off = run(&scenario(), 17);
    let toff = off.trace.expect("tracing armed");
    assert_eq!(stage_spans(&toff), 0, "no stage spans with pipelines off");
    assert_eq!(off.metrics.pipeline_requests, 0);
}

fn tiny_spec() -> SweepSpec {
    let mut base = FleetScenario::walker_631();
    base.sats = 4;
    base.planes = 2;
    base.horizon_hours = 6.0;
    base.interarrival_s = 900.0;
    base.data_gb_lo = 0.05;
    base.data_gb_hi = 0.5;
    SweepSpec {
        name: "trace-determinism".to_string(),
        seed: 5,
        replications: 2,
        base,
        axes: Axes {
            solver: vec!["arg".into(), "ilpb".into()],
            ..Axes::default()
        },
    }
}

#[test]
fn worst_cell_trace_is_identical_across_thread_counts() {
    let spec = tiny_spec();
    let serial = run_sweep(&spec, 1).unwrap();
    let parallel = run_sweep(&spec, 4).unwrap();
    let worst = serial.worst_p99_cell().expect("non-empty sweep");
    assert_eq!(
        parallel.worst_p99_cell(),
        Some(worst),
        "the worst cell must not depend on worker count"
    );
    // the traced re-run (what `--worst-cell-trace` does) is itself
    // deterministic: two re-runs produce byte-identical JSONL
    let cfg = TraceConfig {
        sample_every: Seconds(600.0),
        ..TraceConfig::default()
    };
    let (ra, ta) = run_cell_traced(&serial.cells[worst].cell, cfg.clone()).unwrap();
    let (rb, tb) = run_cell_traced(&parallel.cells[worst].cell, cfg).unwrap();
    assert_eq!(ra.completed, rb.completed);
    assert_eq!(ta.to_jsonl(), tb.to_jsonl(), "worst-cell JSONL must match");
    // and reproduces the swept row exactly
    assert_eq!(ra.completed, serial.cells[worst].completed);
    assert_eq!(ra.p99_latency_s(), serial.cells[worst].p99_latency_s());
}
