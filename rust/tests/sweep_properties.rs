//! Sweep-subsystem acceptance properties:
//!
//! 1. the same `SweepSpec` run with `--threads 1` and `--threads 8`
//!    yields **byte-identical** CSV and JSON exports — deterministic
//!    per-cell seeding survives parallel scheduling;
//! 2. any single cell re-run standalone from its reported seed
//!    reproduces its exported row;
//! 3. the committed CI spec (`specs/ci_sweep.toml`) loads and holds the
//!    same properties, so the CLI smoke check can't drift from what the
//!    tests assert.

use leo_infer::config::FleetScenario;
use leo_infer::exp::{self, Axes, SweepSpec};
use leo_infer::link::isl::IslMode;

/// A grid small enough for the test suite but wide enough to exercise
/// multiple axes, relays, multi-hop routing, and replications:
/// 2 solvers × 2 routings × 2 ISL modes × 2 hop bounds × 2 reps =
/// 32 cells.
fn wide_spec() -> SweepSpec {
    let mut base = FleetScenario::walker_631();
    base.sats = 4;
    base.planes = 2;
    base.phasing = 1;
    base.horizon_hours = 4.0;
    base.interarrival_s = 900.0;
    base.data_gb_lo = 0.05;
    base.data_gb_hi = 0.5;
    base.isl_rate_mbps = 1000.0;
    SweepSpec {
        name: "prop-sweep".to_string(),
        seed: 0x5EED,
        replications: 2,
        base,
        axes: Axes {
            solver: vec!["ilpb".into(), "arg".into()],
            routing: vec!["round-robin".into(), "least-loaded".into()],
            isl: vec![IslMode::Off, IslMode::Grid],
            route: vec![1, 3],
            ..Axes::default()
        },
    }
}

#[test]
fn parallel_and_serial_exports_are_byte_identical() {
    let spec = wide_spec();
    let serial = exp::run_sweep(&spec, 1).unwrap();
    let parallel = exp::run_sweep(&spec, 8).unwrap();
    assert_eq!(serial.cells.len(), 32);
    assert_eq!(
        exp::to_csv(&serial),
        exp::to_csv(&parallel),
        "CSV must not depend on the thread count"
    );
    assert_eq!(
        exp::to_json(&serial).to_string_pretty(),
        exp::to_json(&parallel).to_string_pretty(),
        "JSON must not depend on the thread count"
    );
    // the grid actually exercised the simulator: work completed somewhere
    assert!(serial.cells.iter().any(|c| c.completed > 0));
}

#[test]
fn every_cell_rerun_standalone_reproduces_its_row() {
    let spec = wide_spec();
    let swept = exp::run_sweep(&spec, 4).unwrap();
    for want in &swept.cells {
        let i = want.cell.index;
        // rebuild the cell from nothing but the spec and its index (the
        // reported seed is a pure function of spec.seed and the rep)
        let cell = spec.cell(i);
        assert_eq!(cell.seed, want.cell.seed, "cell {i} seed derivation");
        let lone = exp::run_cell(&cell).unwrap();
        assert_eq!(
            exp::csv_row(&lone),
            exp::csv_row(want),
            "cell {i} standalone re-run must reproduce its exported row"
        );
    }
}

#[test]
fn grouped_aggregates_are_thread_count_invariant() {
    let spec = wide_spec();
    let serial = exp::run_sweep(&spec, 1).unwrap();
    let parallel = exp::run_sweep(&spec, 8).unwrap();
    for axis in ["solver", "routing", "isl", "route", "rep"] {
        let a = exp::comparison_table(&serial, axis).unwrap();
        let b = exp::comparison_table(&parallel, axis).unwrap();
        assert_eq!(a, b, "axis {axis}");
        // pooled group counts tile the grid exactly
        let groups = exp::group_by(&serial, axis).unwrap();
        let submitted: u64 = groups.iter().map(|g| g.submitted).sum();
        assert_eq!(
            submitted,
            serial.cells.iter().map(|c| c.submitted).sum::<u64>(),
            "axis {axis}"
        );
    }
}

#[test]
fn committed_ci_spec_loads_and_is_deterministic() {
    // the file CI feeds to `leo-infer sweep … --verify`; keep it honest
    // even when CI config drifts
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../specs/ci_sweep.toml");
    let spec = SweepSpec::load(path).unwrap().smoke();
    assert_eq!(spec.replications, 1, "--smoke collapses replications");
    assert_eq!(spec.len(), 16, "2 solvers x 2 routings x 2 isl x 2 hop bounds");
    let serial = exp::run_sweep(&spec, 1).unwrap();
    let threaded = exp::run_sweep(&spec, 2).unwrap();
    assert_eq!(exp::to_csv(&serial), exp::to_csv(&threaded));
    assert!(
        serial.cells.iter().all(|c| c.submitted > 0),
        "the committed spec must generate traffic in every cell"
    );
}
