//! CLI end-to-end: the `leo-infer` binary's observability surface.
//!
//! Drives the real binary (`CARGO_BIN_EXE_leo-infer`) through the flows
//! CI scripts rely on: `--timing` prints its breakdown, `--trace` writes
//! a schema-valid export that `trace-validate` accepts, and
//! `bench-schema` distinguishes shape drift from value drift. The
//! [`RunTiming`] invariants themselves are asserted through the library
//! (phases can't exceed the wall clock they partition).

use std::process::Command;

use leo_infer::config::FleetScenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::sim::fleet::FleetSimulator;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_leo-infer"))
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join(format!("leo-infer-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().into_owned()
}

/// `RunTiming` partitions the wall clock: solve + route + dispatch never
/// exceeds the total, and a real run counts real events.
#[test]
fn run_timing_phases_partition_the_wall_clock() {
    let mut scen = FleetScenario::walker_631();
    scen.sats = 4;
    scen.planes = 2;
    scen.horizon_hours = 6.0;
    scen.interarrival_s = 1200.0;
    let mut rng = Pcg64::seeded(41);
    let workload = scen.workload().unwrap().generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(8, &mut rng);
    let mut cfg = scen.sim_config(profile).unwrap();
    cfg.timing = true;
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let result = FleetSimulator::new(cfg).run(&workload, &engine).unwrap();
    let t = result.timing.expect("timing was requested");
    assert!(t.events > 0, "a fleet run must pop events");
    assert!(t.wall_s > 0.0);
    assert!(t.solve_s >= 0.0 && t.route_s >= 0.0 && t.dispatch_s >= 0.0);
    // the phases partition the measured wall time (1 ms slack for timer
    // granularity — the sub-timers nest inside the run's own clock)
    assert!(
        t.solve_s + t.route_s + t.dispatch_s <= t.wall_s + 1e-3,
        "phases {:.6}+{:.6}+{:.6} s exceed wall {:.6} s",
        t.solve_s,
        t.route_s,
        t.dispatch_s,
        t.wall_s
    );
    assert!(t.events_per_sec() > 0.0);
}

/// `--timing` surfaces the breakdown on stdout.
#[test]
fn timing_flag_prints_the_breakdown() {
    let out = bin()
        .args([
            "simulate",
            "--fleet",
            "4/2/1",
            "--hours",
            "6",
            "--interarrival-s",
            "1800",
            "--timing",
        ])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("timing      :") && stdout.contains("events/s"),
        "missing timing block in:\n{stdout}"
    );
}

/// `--trace` writes a JSONL export the validator subcommand accepts, and
/// two identical invocations produce byte-identical files.
#[test]
fn trace_flag_roundtrips_through_trace_validate() {
    let path_a = tmp("cli-trace-a.jsonl");
    let path_b = tmp("cli-trace-b.jsonl");
    for path in [path_a.as_str(), path_b.as_str()] {
        let out = bin()
            .args([
                "simulate",
                "--fleet",
                "4/2/1",
                "--hours",
                "6",
                "--interarrival-s",
                "1800",
                "--trace",
                path,
                "--trace-sample-every",
                "3600",
            ])
            .output()
            .expect("binary runs");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains("trace       :"), "missing receipt in:\n{stdout}");
    }
    let a = std::fs::read(&path_a).unwrap();
    let b = std::fs::read(&path_b).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed + scenario must write identical traces");
    // the library validator agrees with what the CLI wrote...
    let (fmt, summary) =
        leo_infer::obs::validate(&String::from_utf8(a).unwrap()).expect("trace must validate");
    assert_eq!(fmt, leo_infer::obs::TraceFormat::Jsonl);
    assert!(summary.events > 0 && summary.gauges > 0);
    // ...and so does the subcommand CI calls
    let check = bin().args(["trace-validate", path_a.as_str()]).output().unwrap();
    assert!(
        check.status.success(),
        "{}",
        String::from_utf8_lossy(&check.stderr)
    );
    assert!(String::from_utf8_lossy(&check.stdout).contains("valid jsonl trace"));
    // a corrupted file is refused
    std::fs::write(&path_b, "{\"kind\":\"meta\"").unwrap();
    let bad = bin().args(["trace-validate", path_b.as_str()]).output().unwrap();
    assert!(!bad.status.success(), "truncated JSON must fail validation");
}

/// The chrome format loads as JSON with the trace_event envelope.
#[test]
fn chrome_trace_has_the_trace_event_envelope() {
    let path = tmp("cli-trace.json");
    let out = bin()
        .args([
            "simulate",
            "--fleet",
            "4/2/1",
            "--hours",
            "6",
            "--interarrival-s",
            "1800",
            "--trace",
            path.as_str(),
            "--trace-format",
            "chrome",
        ])
        .output()
        .expect("binary runs");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = leo_infer::util::json::Json::parse(&text).expect("chrome export is one JSON doc");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let check = bin().args(["trace-validate", path.as_str()]).output().unwrap();
    assert!(
        check.status.success(),
        "{}",
        String::from_utf8_lossy(&check.stderr)
    );
    assert!(String::from_utf8_lossy(&check.stdout).contains("valid chrome trace"));
}

/// `bench-schema` passes on value drift and fails on shape drift.
#[test]
fn bench_schema_diffs_shape_not_values() {
    let base = tmp("bench-base.json");
    let same_shape = tmp("bench-same.json");
    let drifted = tmp("bench-drift.json");
    std::fs::write(&base, r#"{"bench":"x","rows":[{"n":1,"wall_s":0.5}]}"#).unwrap();
    // different values, same keys and kinds: must pass
    std::fs::write(&same_shape, r#"{"bench":"y","rows":[{"n":9,"wall_s":12.25}]}"#).unwrap();
    // a key changed kind: must fail
    std::fs::write(&drifted, r#"{"bench":"x","rows":[{"n":"one","wall_s":0.5}]}"#).unwrap();
    let ok = bin()
        .args(["bench-schema", base.as_str(), same_shape.as_str()])
        .output()
        .unwrap();
    assert!(
        ok.status.success(),
        "{}",
        String::from_utf8_lossy(&ok.stderr)
    );
    let bad = bin()
        .args(["bench-schema", base.as_str(), drifted.as_str()])
        .output()
        .unwrap();
    assert!(!bad.status.success(), "kind drift must fail the diff");
    assert!(String::from_utf8_lossy(&bad.stderr).contains("schema mismatch"));
}

/// The committed repo-root baseline stays parseable and smoke-shaped —
/// the schema CI diffs fresh bench output against.
#[test]
fn committed_bench_baseline_is_valid_json() {
    let text = std::fs::read_to_string("../BENCH_fleet.json")
        .expect("BENCH_fleet.json must be committed at the repo root");
    let doc = leo_infer::util::json::Json::parse(&text).unwrap();
    for key in ["bench", "smoke", "scaling", "isl_overhead", "walker_40_40"] {
        assert!(doc.get(key).is_ok(), "baseline missing `{key}`");
    }
}
