//! Failure-mode coverage for the AOT artifact manifest loader, plus the
//! measured-profile → solver round trip — all over synthetic manifests
//! written to the OS temp dir, so the tests run whether or not the real
//! compiled artifacts exist.

use leo_infer::config::Scenario;
use leo_infer::placement::ModelArtifact;
use leo_infer::runtime::artifacts::Manifest;
use leo_infer::solver::{SolveRequest, SolverRegistry};
use std::path::PathBuf;

/// A fresh manifest dir under the OS temp dir. Each test passes its own
/// tag so parallel test threads never collide.
fn setup(tag: &str, manifest_json: &str, stage_files: &[(&str, usize)]) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("leo_infer_manifest_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    for (name, bytes) in stage_files {
        std::fs::write(dir.join(name), vec![0u8; *bytes]).unwrap();
    }
    std::fs::write(dir.join("manifest.json"), manifest_json).unwrap();
    dir
}

/// A consistent two-stage, batch-1 manifest: a 256-element input
/// (1024 B at f32), a 64-element boundary tensor, 10-element logits.
fn valid_json() -> String {
    r#"{
  "model": "tiny2",
  "batch_sizes": [1],
  "stages": [
    {
      "index": 0, "name": "s0", "batch": 1,
      "in_shape": [1, 8, 8, 4], "out_shape": [1, 4, 4, 4],
      "in_bytes": 1024, "out_bytes": 256,
      "path": "s0.bin"
    },
    {
      "index": 1, "name": "s1", "batch": 1,
      "in_shape": [1, 4, 4, 4], "out_shape": [1, 10],
      "in_bytes": 256, "out_bytes": 40,
      "path": "s1.bin"
    }
  ]
}"#
    .to_string()
}

/// The lowered-executable files the valid manifest points at.
const STAGES: [(&str, usize); 2] = [("s0.bin", 7000), ("s1.bin", 3000)];

#[test]
fn missing_dir_and_garbage_json_fail_cleanly() {
    let err = Manifest::load("/nonexistent/nowhere").unwrap_err().to_string();
    assert!(err.contains("manifest.json"), "unhelpful error: {err}");
    let dir = setup("garbage", "{ not json at all", &[]);
    assert!(Manifest::load(&dir).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_stage_file_fails_validation() {
    // manifest names s1.bin but only s0.bin exists on disk
    let dir = setup("missing_file", &valid_json(), &STAGES[..1]);
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("missing artifact file"), "unhelpful error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batch_without_stages_fails_validation() {
    // batch_sizes promises an 8-variant no stage provides
    let json = valid_json().replace("\"batch_sizes\": [1]", "\"batch_sizes\": [1, 8]");
    let dir = setup("batch_gap", &json, &STAGES);
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(
        err.contains("batch 8: expected 2 stages, found 0"),
        "unhelpful error: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn broken_shape_chain_fails_validation() {
    // stage 1 consumes [1, 64] while stage 0 produces [1, 4, 4, 4]
    // (same element count, so in_bytes stays self-consistent — only the
    // chain check can catch it)
    let json = valid_json().replace("\"in_shape\": [1, 4, 4, 4]", "\"in_shape\": [1, 64]");
    let dir = setup("shape_chain", &json, &STAGES);
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(
        err.contains("shape chain broken at s0 → s1"),
        "unhelpful error: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn inconsistent_in_bytes_fails_validation() {
    let json = valid_json().replace("\"in_bytes\": 1024", "\"in_bytes\": 999");
    let dir = setup("bad_bytes", &json, &STAGES);
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(
        err.contains("s0: in_bytes inconsistent with shape"),
        "unhelpful error: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn measured_profile_round_trips_into_a_solvable_instance() {
    let dir = setup("roundtrip", &valid_json(), &STAGES);
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.depth(), 2);
    let profile = m.measured_profile(1).unwrap();
    assert_eq!(profile.depth(), 2);
    // absent batch variants are refused, not silently empty
    let err = m.measured_profile(4).unwrap_err().to_string();
    assert!(err.contains("no stages for batch 4"), "unhelpful error: {err}");
    // the measured sizes drive a real solve end to end
    let inst = Scenario::tiansuan().instance_builder(profile).build().unwrap();
    let engine = SolverRegistry::engine("ilpb").unwrap();
    let outcome = engine.solve(&SolveRequest::new(inst.clone()));
    assert!(outcome.decision.split <= inst.depth());
    assert!(outcome.decision.z.is_finite());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn from_manifest_footprints_the_on_disk_stage_files() {
    let dir = setup("footprint", &valid_json(), &STAGES);
    let m = Manifest::load(&dir).unwrap();
    let art = ModelArtifact::from_manifest(3, &m, 1).unwrap();
    assert_eq!(art.id, 3);
    assert_eq!(art.name, "tiny2");
    // stage bytes come from fs metadata of the lowered executables
    assert_eq!(art.total_bytes().value(), 10_000.0);
    assert_eq!(art.bytes_up_to(0).value(), 0.0);
    assert_eq!(art.bytes_up_to(1).value(), 7000.0);
    assert_eq!(art.bytes_up_to(2).value(), 10_000.0);
    let err = ModelArtifact::from_manifest(0, &m, 4).unwrap_err().to_string();
    assert!(err.contains("no stages for batch 4"), "unhelpful error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
