//! The determinism lints (see `docs/LINTS.md` for the full catalogue).
//!
//! Four rules, each protecting a bit-identity or no-NaN-panic guarantee
//! the simulator's regression suite depends on:
//!
//! * `hash_iter` — no `HashMap`/`HashSet` in the sources: their
//!   iteration order is nondeterministic and one stray `for` over a
//!   hash table can leak into DES event order, routing, metrics, or
//!   sweep exports. Lookup-only uses are annotated with `lint:allow`.
//! * `wall_clock` — no `Instant`/`SystemTime`/`thread_rng` outside the
//!   allowlisted timing harnesses: simulated time must come from the
//!   event queue, randomness from `util::rng`.
//! * `float_ord` — no `partial_cmp` in `solver/`, `link/`, `sim/`,
//!   `coordinator/`: float orderings there must use `f64::total_cmp`
//!   (or the shared `precedes` helper) so a NaN can never panic or
//!   reorder a comparator.
//! * `tx_state` — transmitter state (`tx_free`/`tx_free_at`) may only
//!   be written through the `route_gen`-bumping setter
//!   (`HotPath::touch_tx`), so the route cache can never go stale.
//!
//! Every rule honours `// lint:allow(<rule>, reason = "...")` on the
//! same or the preceding line; an allow without a reason is itself a
//! violation (`allow_syntax`).

use crate::scan::{scan, Allow};

/// The rule names accepted by `lint:allow`.
pub const RULES: [&str; 4] = ["hash_iter", "wall_clock", "float_ord", "tx_state"];

/// Files (relative to `rust/src`, `/`-separated) where wall-clock and
/// ambient-randomness sources are legitimate: the RNG itself, logging
/// timestamps, the CLI front-end, and the opt-in `--timing` harnesses.
const WALL_CLOCK_ALLOWED_FILES: [&str; 5] = [
    "util/rng.rs",
    "util/logging.rs",
    "main.rs",
    "sim/fleet.rs",
    "solver/engine/mod.rs",
];

/// Directories whose float comparators feed deterministic decisions.
const FLOAT_ORD_DIRS: [&str; 4] = ["solver/", "link/", "sim/", "coordinator/"];

/// One lint finding, pointing at a file/line pair.
#[derive(Debug)]
pub struct Violation {
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Rule that fired (`allow_syntax` for malformed directives).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub msg: String,
}

/// Lint one file. Returns the violations plus non-fatal warnings
/// (currently: allow directives that excused nothing).
pub fn lint_file(rel: &str, src: &str) -> (Vec<Violation>, Vec<String>) {
    let scanned = scan(src);
    let mut out = Vec::new();
    let mut used = vec![false; scanned.allows.len()];

    for a in &scanned.allows {
        if !a.reason_ok {
            out.push(Violation {
                file: rel.to_owned(),
                line: a.line,
                rule: "allow_syntax",
                msg: "malformed allow — expected lint:allow(<rule>, reason = \"...\") \
                      with a non-empty reason"
                    .to_owned(),
            });
        } else if !RULES.contains(&a.rule.as_str()) {
            out.push(Violation {
                file: rel.to_owned(),
                line: a.line,
                rule: "allow_syntax",
                msg: format!("unknown rule `{}` in lint:allow", a.rule),
            });
        }
    }

    let wall_clock_applies = !WALL_CLOCK_ALLOWED_FILES.contains(&rel);
    let float_ord_applies = FLOAT_ORD_DIRS.iter().any(|d| rel.starts_with(d));
    let tx_state_applies = rel.starts_with("sim/") && rel != "sim/entities.rs";

    for (idx, text) in scanned.lines.iter().enumerate() {
        let line = idx + 1;
        if (has_token(text, "HashMap") || has_token(text, "HashSet"))
            && !allowed(&scanned.allows, &mut used, "hash_iter", line)
        {
            out.push(Violation {
                file: rel.to_owned(),
                line,
                rule: "hash_iter",
                msg: "HashMap/HashSet iteration order is nondeterministic; use \
                      BTreeMap/BTreeSet, sort before iterating, or annotate a \
                      lookup-only use"
                    .to_owned(),
            });
        }
        if wall_clock_applies {
            for tok in ["Instant", "SystemTime", "thread_rng"] {
                if has_token(text, tok) {
                    if !allowed(&scanned.allows, &mut used, "wall_clock", line) {
                        out.push(Violation {
                            file: rel.to_owned(),
                            line,
                            rule: "wall_clock",
                            msg: format!(
                                "`{tok}` outside the allowlist; simulated time comes \
                                 from the event queue, randomness from util::rng"
                            ),
                        });
                    }
                    break;
                }
            }
        }
        if float_ord_applies
            && has_partial_cmp_use(text)
            && !allowed(&scanned.allows, &mut used, "float_ord", line)
        {
            out.push(Violation {
                file: rel.to_owned(),
                line,
                rule: "float_ord",
                msg: "float ordering via partial_cmp is a NaN panic/ordering hazard \
                      here; use f64::total_cmp"
                    .to_owned(),
            });
        }
        if tx_state_applies
            && has_tx_assignment(text)
            && !allowed(&scanned.allows, &mut used, "tx_state", line)
        {
            out.push(Violation {
                file: rel.to_owned(),
                line,
                rule: "tx_state",
                msg: "transmitter state must be mutated through the route_gen-bumping \
                      setter (HotPath::touch_tx) so cached routes are invalidated"
                    .to_owned(),
            });
        }
    }

    let mut warnings = Vec::new();
    for (i, a) in scanned.allows.iter().enumerate() {
        if a.reason_ok && RULES.contains(&a.rule.as_str()) && !used[i] {
            warnings.push(format!(
                "{rel}:{}: lint:allow({}) excuses nothing (stale directive?)",
                a.line, a.rule
            ));
        }
    }
    (out, warnings)
}

/// Does any well-formed allow for `rule` cover `line`? Marks it used.
fn allowed(allows: &[Allow], used: &mut [bool], rule: &str, line: usize) -> bool {
    let mut hit = false;
    for (i, a) in allows.iter().enumerate() {
        if a.reason_ok && a.rule == rule && (a.line == line || a.line + 1 == line) {
            used[i] = true;
            hit = true;
        }
    }
    hit
}

/// Whole-word occurrence check: `tok` bounded by non-identifier bytes.
fn has_token(text: &str, tok: &str) -> bool {
    !token_starts(text, tok).is_empty()
}

/// Byte offsets of whole-word occurrences of `tok` in `text`.
fn token_starts(text: &str, tok: &str) -> Vec<usize> {
    let bytes = text.as_bytes();
    let mut found = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = text[from..].find(tok) {
        let start = from + pos;
        let end = start + tok.len();
        let left_ok = start == 0 || !is_ident(bytes[start - 1]);
        let right_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if left_ok && right_ok {
            found.push(start);
        }
        from = start + 1;
    }
    found
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// A `partial_cmp` token that is a *use*, not the `fn partial_cmp`
/// definition inside a `PartialOrd` impl.
fn has_partial_cmp_use(text: &str) -> bool {
    token_starts(text, "partial_cmp").iter().any(|&start| {
        let head = text[..start].trim_end();
        let is_def = head.ends_with("fn")
            && (head.len() == 2 || !is_ident(head.as_bytes()[head.len() - 3]));
        !is_def
    })
}

/// A write to `tx_free`/`tx_free_at`: the token followed (on the same
/// line) by an assignment operator — a bare `=` or a compound `+=`-style
/// one, but not `==`, `<=`, `>=`, `!=`, or `=>`.
fn has_tx_assignment(text: &str) -> bool {
    let bytes = text.as_bytes();
    for tok in ["tx_free", "tx_free_at"] {
        for &start in &token_starts(text, tok) {
            let mut p = start + tok.len();
            while p < bytes.len() {
                if bytes[p] == b'=' {
                    let prev = bytes[p - 1];
                    let next = bytes.get(p + 1).copied();
                    let comparison = matches!(prev, b'=' | b'!' | b'<' | b'>')
                        || matches!(next, Some(b'=') | Some(b'>'));
                    if !comparison {
                        return true;
                    }
                }
                p += 1;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<&'static str> {
        lint_file(rel, src).0.into_iter().map(|v| v.rule).collect()
    }

    // --- hash_iter ------------------------------------------------------

    #[test]
    fn hash_iter_flags_hashmap_and_hashset() {
        assert_eq!(
            rules_fired("exp/grid.rs", "use std::collections::HashMap;\n"),
            vec!["hash_iter"]
        );
        assert_eq!(
            rules_fired("sim/fleet.rs", "let s: HashSet<u64> = HashSet::new();\n"),
            vec!["hash_iter"]
        );
    }

    #[test]
    fn hash_iter_passes_btreemap_and_comments() {
        assert!(rules_fired("exp/grid.rs", "use std::collections::BTreeMap;\n").is_empty());
        assert!(rules_fired("exp/grid.rs", "// a HashMap would be wrong here\n").is_empty());
        let lowercase_path = "use std::collections::hash_map::DefaultHasher;\n";
        assert!(rules_fired("util/hash.rs", lowercase_path).is_empty());
    }

    #[test]
    fn hash_iter_allow_with_reason_suppresses() {
        let src = "use std::collections::HashMap; // lint:allow(hash_iter, reason = \"O(1) \
                   lookups only; the intrusive list provides order\")\n";
        let (violations, warnings) = lint_file("util/lru.rs", src);
        assert!(violations.is_empty());
        assert!(warnings.is_empty());
    }

    #[test]
    fn allow_without_reason_is_a_violation() {
        let src = "use std::collections::HashMap; // lint:allow(hash_iter)\n";
        let fired = rules_fired("util/lru.rs", src);
        assert!(fired.contains(&"allow_syntax"));
        assert!(fired.contains(&"hash_iter"), "a reasonless allow must not suppress");
    }

    #[test]
    fn allow_with_unknown_rule_is_a_violation() {
        let src = "// lint:allow(no_such_rule, reason = \"nope\")\nlet x = 1;\n";
        assert_eq!(rules_fired("sim/fleet.rs", src), vec!["allow_syntax"]);
    }

    #[test]
    fn unused_allow_warns() {
        let src = "// lint:allow(hash_iter, reason = \"left over\")\nlet x = 1;\n";
        let (violations, warnings) = lint_file("sim/fleet.rs", src);
        assert!(violations.is_empty());
        assert_eq!(warnings.len(), 1);
    }

    // --- wall_clock -----------------------------------------------------

    #[test]
    fn wall_clock_flags_instant_outside_allowlist() {
        assert_eq!(
            rules_fired("sim/engine.rs", "let t0 = Instant::now();\n"),
            vec!["wall_clock"]
        );
        assert_eq!(
            rules_fired("coordinator/server.rs", "let r = thread_rng();\n"),
            vec!["wall_clock"]
        );
        assert_eq!(
            rules_fired("exp/grid.rs", "let t = std::time::SystemTime::now();\n"),
            vec!["wall_clock"]
        );
    }

    #[test]
    fn wall_clock_passes_allowlisted_files_and_strings() {
        assert!(rules_fired("main.rs", "let t0 = Instant::now();\n").is_empty());
        assert!(rules_fired("util/rng.rs", "let r = thread_rng();\n").is_empty());
        assert!(rules_fired("sim/fleet.rs", "let t0 = Instant::now();\n").is_empty());
        assert!(rules_fired("sim/engine.rs", "let s = \"Instant::now\";\n").is_empty());
    }

    #[test]
    fn wall_clock_allow_on_previous_line_suppresses() {
        let src = "// lint:allow(wall_clock, reason = \"test-only wait loop\")\n\
                   let deadline = std::time::Instant::now();\n";
        let (violations, warnings) = lint_file("coordinator/server.rs", src);
        assert!(violations.is_empty());
        assert!(warnings.is_empty());
    }

    // --- float_ord ------------------------------------------------------

    #[test]
    fn float_ord_flags_partial_cmp_in_watched_dirs() {
        let src = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert_eq!(rules_fired("solver/bnb.rs", src), vec!["float_ord"]);
        assert_eq!(rules_fired("link/route.rs", src), vec!["float_ord"]);
        assert_eq!(rules_fired("coordinator/router.rs", src), vec!["float_ord"]);
    }

    #[test]
    fn float_ord_passes_total_cmp_definitions_and_other_dirs() {
        assert!(rules_fired("sim/engine.rs", "xs.sort_by(|a, b| a.total_cmp(b));\n").is_empty());
        let def = "fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n";
        assert!(rules_fired("sim/engine.rs", def).is_empty(), "trait impl is a definition");
        let usage = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert!(rules_fired("util/stats.rs", usage).is_empty(), "outside watched dirs");
    }

    // --- tx_state -------------------------------------------------------

    #[test]
    fn tx_state_flags_direct_writes() {
        let plain = "self.tx_free[sat] = free_at;\n";
        assert_eq!(rules_fired("sim/fleet.rs", plain), vec!["tx_state"]);
        let field = "state.tx_free_at = 0.0;\n";
        assert_eq!(rules_fired("sim/runner.rs", field), vec!["tx_state"]);
        let compound = "hot.tx_free[s] += 1.0;\n";
        assert_eq!(
            rules_fired("sim/fleet.rs", compound),
            vec!["tx_state"],
            "compound assignment is still a write"
        );
    }

    #[test]
    fn tx_state_passes_reads_comparisons_and_entities() {
        assert!(rules_fired("sim/fleet.rs", "let t = now.max(hot.tx_free[sat]);\n").is_empty());
        let cmp = "if a.tx_free_at <= b.tx_free_at { f(); }\n";
        assert!(rules_fired("sim/fleet.rs", cmp).is_empty());
        assert!(rules_fired("sim/fleet.rs", "let eq = x.tx_free_at == y;\n").is_empty());
        assert!(
            rules_fired("sim/entities.rs", "self.tx_free_at = now;\n").is_empty(),
            "the owning struct may initialise its own field"
        );
        assert!(rules_fired("link/route.rs", "peer.tx_free_at = 0.0;\n").is_empty());
    }

    #[test]
    fn tx_state_allow_suppresses_the_sanctioned_setter() {
        let src = "// lint:allow(tx_state, reason = \"this IS the setter\")\n\
                   self.tx_free[sat] = free_at;\n";
        let (violations, warnings) = lint_file("sim/fleet.rs", src);
        assert!(violations.is_empty());
        assert!(warnings.is_empty());
    }
}
