//! `cargo xtask` — project tooling for the leo-infer workspace.
//!
//! The only subcommand today is `lint`, which runs the determinism
//! rules from [`rules`] over every `.rs` file under `rust/src` (or a
//! `--root` override) and exits non-zero on any unallowed violation.
//! See `docs/LINTS.md` for the rule catalogue and the
//! `lint:allow(<rule>, reason = "...")` escape hatch.

mod rules;
mod scan;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(&args[1..]),
        _ => {
            eprintln!("usage: cargo xtask lint [--root <src dir>]");
            ExitCode::from(2)
        }
    }
}

fn lint(args: &[String]) -> ExitCode {
    let mut root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("xtask lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("xtask lint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }

    let mut files = Vec::new();
    if let Err(e) = collect_rs(&root, &mut files) {
        eprintln!("xtask lint: cannot walk {}: {e}", root.display());
        return ExitCode::from(2);
    }
    files.sort();

    let mut violations = 0usize;
    let mut warnings = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("xtask lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let (found, warns) = rules::lint_file(&rel, &src);
        for v in &found {
            println!("{}:{}: [{}] {}", path.display(), v.line, v.rule, v.msg);
        }
        for w in &warns {
            println!("warning: {w}");
        }
        violations += found.len();
        warnings += warns.len();
    }

    if violations == 0 {
        println!(
            "lint: {} files clean ({} warning{})",
            files.len(),
            warnings,
            if warnings == 1 { "" } else { "s" }
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "lint: {violations} violation{} across {} files",
            if violations == 1 { "" } else { "s" },
            files.len()
        );
        ExitCode::FAILURE
    }
}

/// Recursively collect `.rs` files under `dir`.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
