//! Comment/string-aware source scanner for the determinism lints.
//!
//! The lints in [`crate::rules`] are token-level, so the scanner's job is
//! to (1) blank out everything that is *not* code — line comments, block
//! comments (nested), string literals (including raw strings and byte
//! strings), and char literals — while preserving line structure, and
//! (2) extract `lint:allow(<rule>, reason = "...")` directives from the
//! comments it blanks. Lifetimes (`'a`) are kept as code so a stray
//! apostrophe never swallows the rest of a line.
//!
//! This is deliberately not a parser: the rules only need identifier
//! tokens with correct comment/string classification, and a hand-rolled
//! scanner keeps the crate dependency-free for the offline build.

/// A `lint:allow` directive extracted from a comment.
#[derive(Debug, Clone)]
pub struct Allow {
    /// Rule name inside the parentheses (empty when malformed).
    pub rule: String,
    /// 1-based line the directive's comment starts on. The directive
    /// covers this line and the next, so it works both as a trailing
    /// comment and as a comment line above the code it excuses.
    pub line: usize,
    /// Whether the directive carries a non-empty `reason = "..."`.
    pub reason_ok: bool,
}

/// One scanned file: code-only lines plus the allow directives found.
#[derive(Debug)]
pub struct Scanned {
    /// Source lines with comments/strings/chars blanked to spaces.
    pub lines: Vec<String>,
    /// Every `lint:allow` directive, malformed ones included.
    pub allows: Vec<Allow>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

/// Strip comments and literals from `src`, collecting allow directives.
pub fn scan(src: &str) -> Scanned {
    let bytes = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(bytes.len());
    let mut allows = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < bytes.len() {
        let b = bytes[i];
        // Line comment: blank it, but mine it for allow directives.
        if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < bytes.len() && bytes[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            if let Some(a) = parse_allow(&src[start..i], line) {
                allows.push(a);
            }
            continue;
        }
        // Block comment (Rust block comments nest).
        if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            out.extend_from_slice(b"  ");
            i += 2;
            while i < bytes.len() && depth > 0 {
                if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else {
                    if bytes[i] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                    } else {
                        out.push(b' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: r"..", r#".."#, br#".."# — only when the
        // prefix is not the tail of a longer identifier.
        if (b == b'r' || b == b'b') && (i == 0 || !is_ident(bytes[i - 1])) {
            if let Some((open_len, hashes)) = raw_string_open(&bytes[i..]) {
                out.resize(out.len() + open_len, b' ');
                i += open_len;
                loop {
                    if i >= bytes.len() {
                        break;
                    }
                    if bytes[i] == b'"' && closes_raw(&bytes[i + 1..], hashes) {
                        out.resize(out.len() + 1 + hashes, b' ');
                        i += 1 + hashes;
                        break;
                    }
                    if bytes[i] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                    } else {
                        out.push(b' ');
                    }
                    i += 1;
                }
                continue;
            }
        }
        // Ordinary (or byte) string literal, escapes honoured.
        if b == b'"' {
            out.push(b' ');
            i += 1;
            while i < bytes.len() {
                if bytes[i] == b'\\' && i + 1 < bytes.len() {
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if bytes[i] == b'"' {
                    out.push(b' ');
                    i += 1;
                    break;
                } else {
                    if bytes[i] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                    } else {
                        out.push(b' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Apostrophe: lifetime (keep as code) or char literal (blank).
        if b == b'\'' {
            if is_lifetime(&bytes[i + 1..]) {
                out.push(b'\'');
                i += 1;
                continue;
            }
            out.push(b' ');
            i += 1;
            while i < bytes.len() {
                if bytes[i] == b'\\' && i + 1 < bytes.len() {
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if bytes[i] == b'\'' {
                    out.push(b' ');
                    i += 1;
                    break;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            continue;
        }
        if b == b'\n' {
            line += 1;
        }
        out.push(b);
        i += 1;
    }

    let text = String::from_utf8_lossy(&out).into_owned();
    Scanned {
        lines: text.lines().map(str::to_owned).collect(),
        allows,
    }
}

/// `bytes` starts right after an apostrophe: is this a lifetime?
/// A lifetime is an identifier not followed by a closing quote
/// (so `'a'` is a char literal but `'a>` / `'a,` are lifetimes).
fn is_lifetime(bytes: &[u8]) -> bool {
    match bytes.first() {
        Some(&b) if is_ident_start(b) => {}
        _ => return false,
    }
    let mut j = 1;
    while j < bytes.len() && is_ident(bytes[j]) {
        j += 1;
    }
    bytes.get(j) != Some(&b'\'')
}

/// Match a raw-string opener at the start of `bytes` (`r`, `br` plus
/// zero or more `#` then `"`). Returns (prefix length, hash count).
fn raw_string_open(bytes: &[u8]) -> Option<(usize, usize)> {
    let mut j = 0usize;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while bytes.get(j + hashes) == Some(&b'#') {
        hashes += 1;
    }
    if bytes.get(j + hashes) == Some(&b'"') {
        Some((j + hashes + 1, hashes))
    } else {
        None
    }
}

/// `bytes` starts right after a `"`: do `hashes` hash marks follow?
fn closes_raw(bytes: &[u8], hashes: usize) -> bool {
    bytes.len() >= hashes && bytes[..hashes].iter().all(|&b| b == b'#')
}

/// Parse a `lint:allow(<rule>, reason = "...")` directive out of one
/// comment. Returns `None` when the comment has no directive at all;
/// malformed directives come back with `reason_ok: false` so the lint
/// driver can reject them (an allow without a reason is itself a
/// violation). Reasons must not contain `)`.
fn parse_allow(comment: &str, line: usize) -> Option<Allow> {
    let idx = comment.find("lint:allow")?;
    let rest = &comment[idx + "lint:allow".len()..];
    let malformed = Some(Allow {
        rule: String::new(),
        line,
        reason_ok: false,
    });
    let Some(open) = rest.strip_prefix('(') else {
        return malformed;
    };
    let Some((body, _)) = open.split_once(')') else {
        return malformed;
    };
    let (rule, reason) = match body.split_once(',') {
        Some((r, rest)) => (r.trim(), Some(rest.trim())),
        None => (body.trim(), None),
    };
    if rule.is_empty() || !rule.bytes().all(is_ident) {
        return malformed;
    }
    let reason_ok = reason
        .and_then(|r| r.strip_prefix("reason"))
        .map(|r| r.trim_start())
        .and_then(|r| r.strip_prefix('='))
        .map(|r| r.trim_start())
        .and_then(|r| r.strip_prefix('"'))
        .and_then(|r| r.split_once('"'))
        .is_some_and(|(text, _)| !text.trim().is_empty());
    Some(Allow {
        rule: rule.to_owned(),
        line,
        reason_ok,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code(src: &str) -> String {
        scan(src).lines.join("\n")
    }

    #[test]
    fn line_comments_are_blanked() {
        let s = code("let x = 1; // HashMap here\nlet y = 2;");
        assert!(!s.contains("HashMap"));
        assert!(s.contains("let x = 1;"));
        assert!(s.contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comments_are_blanked() {
        let s = code("a /* outer /* Instant */ still comment */ b");
        assert!(!s.contains("Instant"));
        assert!(!s.contains("still"));
        assert!(s.starts_with('a'));
        assert!(s.trim_end().ends_with('b'));
    }

    #[test]
    fn strings_and_raw_strings_are_blanked() {
        let s = code("let a = \"Instant::now\"; let b = r#\"thread_rng \"x\" \"#; f(a)");
        assert!(!s.contains("Instant"));
        assert!(!s.contains("thread_rng"));
        assert!(s.contains("f(a)"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let s = code("let a = \"x\\\"SystemTime\"; g()");
        assert!(!s.contains("SystemTime"));
        assert!(s.contains("g()"));
    }

    #[test]
    fn char_literals_are_blanked_but_lifetimes_survive() {
        let s = code("fn f<'a>(x: &'a str) { let q = '\"'; let n = '\\n'; }");
        assert!(s.contains("<'a>"));
        assert!(s.contains("&'a str"));
        assert!(!s.contains('"'));
        let s2 = code("let c = 'I'; Instant");
        assert!(s2.contains("Instant"));
        assert!(!s2.contains("'I'"));
    }

    #[test]
    fn line_structure_is_preserved() {
        let scanned = scan("a\n/* two\nlines */\nb\n");
        assert_eq!(scanned.lines.len(), 4);
        assert_eq!(scanned.lines[3].trim(), "b");
    }

    #[test]
    fn allow_with_reason_parses() {
        let scanned = scan("// lint:allow(hash_iter, reason = \"lookup only\")\nuse x;\n");
        assert_eq!(scanned.allows.len(), 1);
        let a = &scanned.allows[0];
        assert_eq!(a.rule, "hash_iter");
        assert_eq!(a.line, 1);
        assert!(a.reason_ok);
    }

    #[test]
    fn allow_without_reason_is_flagged_malformed() {
        let scanned = scan("let x = 1; // lint:allow(wall_clock)\n");
        assert_eq!(scanned.allows.len(), 1);
        assert!(!scanned.allows[0].reason_ok);
        let scanned = scan("// lint:allow(wall_clock, reason = \"\")\n");
        assert!(!scanned.allows[0].reason_ok);
    }

    #[test]
    fn trailing_allow_records_its_own_line() {
        let scanned = scan("line1\nlet m = x; // lint:allow(tx_state, reason = \"setter\")\n");
        assert_eq!(scanned.allows[0].line, 2);
    }
}
