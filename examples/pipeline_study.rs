//! Pipeline study: multi-node placement vectors vs the paper's single
//! split, in a compute-starved fleet.
//!
//! ```bash
//! cargo run --release --example pipeline_study            # full study
//! cargo run --release --example pipeline_study -- --smoke # CI-sized run
//! ```
//!
//! Three satellites on a line — the serving satellite reaches only its
//! in-plane neighbor, which is 5× faster — under a prohibitive 0.1 Mbps
//! downlink and a pure-latency objective. The first DNN layer shrinks the
//! tensor 10× (α = [1, 0.1, 0.1]), so the interesting placement is a
//! genuine *cut vector*: compute layer 0 at home where the raw capture
//! already sits, ship the small boundary tensor over the 0.64 Mbps ISL,
//! and finish layers 1–2 on the fast neighbor. Per 8 MB capture
//! (β = 1e-5 s/byte):
//!
//! * bent pipe / best single split — everything on the serving
//!   satellite: ≈ 100.7 s (offloading any suffix over the slow downlink
//!   costs hundreds of seconds more);
//! * ship the raw input to the fast neighbor (cuts `[0,3,3]`): ≈ 125 s —
//!   the 10× heavier pre-layer-0 tensor eats the compute advantage;
//! * two-stage placement (cuts `[1,3,3]`): ≈ 97.7 s.
//!
//! The study runs the *same* capture trace through the bent pipe, the
//! single-split fleet with ISLs, and the pipeline-enabled fleet, then
//! asserts the headline result — the multi-node placement strictly beats
//! the best single split — so CI fails if the pipeline path ever rots.

use leo_infer::dnn::profile::ModelProfile;
use leo_infer::link::isl::{IslMode, IslTopology};
use leo_infer::orbit::constellation::{Constellation, NamedOrbit};
use leo_infer::orbit::propagator::CircularOrbit;
use leo_infer::placement::PlacementConfig;
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::fleet::{
    FleetSimConfig, FleetSimulator, PipelineConfig, SatelliteSpec, TelemetryMode,
};
use leo_infer::sim::workload::Request;
use leo_infer::sim::SimMetrics;
use leo_infer::solver::instance::InstanceBuilder;
use leo_infer::solver::SolverRegistry;
use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds};

/// Line topology 0 – 1 – 2 with every range < 1000 km, so each link runs
/// at exactly the reference rate (the inverse-square range scaling caps
/// out) and the per-capture arithmetic in the module docs is exact.
fn line3(rate_mbps: f64) -> IslTopology {
    let mk = |plane: usize, slot: usize, raan: f64, phase: f64| NamedOrbit {
        name: format!("p{plane}s{slot}"),
        plane,
        slot,
        orbit: CircularOrbit::new(550.0, 53.0, raan, phase),
    };
    let c = Constellation {
        satellites: vec![mk(0, 1, 0.0, 2.0), mk(0, 0, 0.0, 0.0), mk(1, 0, 2.0, 0.0)],
    };
    IslTopology::build(&c, IslMode::Grid, BitsPerSec::from_mbps(rate_mbps))
        .expect("line topology builds")
}

fn fleet(pipeline: Option<PipelineConfig>, isl: bool) -> FleetSimConfig {
    let prof = ModelProfile::from_alphas("pipe-net", &[1000.0, 100.0, 100.0, 100.0])
        .expect("profile shape is valid");
    let template = InstanceBuilder::new(prof.clone())
        .beta_s_per_kb(1024.0 * 1e-5) // β = 1e-5 s per byte
        .rate(BitsPerSec::from_mbps(0.1)) // downlink prohibitive
        .weights(0.0, 1.0) // pure latency objective
        .contact(Seconds::from_hours(8.0), Seconds::from_minutes(6.0));
    let mut sats: Vec<SatelliteSpec> = (0..3)
        .map(|i| {
            let contact =
                PeriodicContact::new(Seconds::from_hours(8.0), Seconds::from_minutes(6.0))
                    .with_phase(Seconds(i as f64 * 100.0));
            SatelliteSpec::new(&format!("sat-{i}"), Box::new(contact))
        })
        .collect();
    sats[1].compute_scale = 5.0; // the fast neighbor
    FleetSimConfig {
        template,
        profiles: vec![prof],
        sats,
        routing: RoutingPolicy::LeastLoaded,
        isl: if isl { Some(line3(0.64)) } else { None },
        isl_max_hops: 4,
        telemetry: TelemetryMode::Unconstrained,
        placement: PlacementConfig::default(),
        route_cache: true,
        timing: false,
        audit: true, // slot/battery invariants checked throughout
        trace: None,
        pipeline,
        horizon: Seconds::from_hours(10_000.0),
    }
}

/// Evenly spaced 8 MB captures: each finishes (~100 s) before the next
/// arrives, so every variant serves every capture from satellite 0 and
/// the latency gap is pure placement quality, not queueing noise.
fn captures(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            arrival: Seconds(10.0 + i as f64 * 300.0),
            data: Bytes::from_mb(8.0),
            model: 0,
            class: 1,
        })
        .collect()
}

fn run(cfg: FleetSimConfig, trace: &[Request]) -> anyhow::Result<SimMetrics> {
    let engine = SolverRegistry::engine("exhaustive")?;
    Ok(FleetSimulator::new(cfg).run(trace, &engine)?.metrics)
}

fn row(label: &str, m: &SimMetrics) {
    let multi = m.records.iter().filter(|r| r.stages > 1).count();
    println!(
        "{:<22} {:>9} {:>10} {:>12} {:>13.2} {:>12.2}",
        label,
        m.completed(),
        m.pipeline_requests,
        multi,
        m.mean_latency().value(),
        m.total_energy().value(),
    );
}

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let trace = captures(if smoke { 6 } else { 24 });
    println!(
        "pipeline study{}: 3-sat line, neighbor 5x faster, 8 MB captures,\n\
         0.64 Mbps ISL, 0.1 Mbps downlink, pure-latency objective, {} captures\n",
        if smoke { " (smoke)" } else { "" },
        trace.len(),
    );

    let bent = run(fleet(None, false), &trace)?;
    let single = run(fleet(None, true), &trace)?;
    let piped = run(fleet(Some(PipelineConfig { max_nodes: 3 }), true), &trace)?;

    println!(
        "{:<22} {:>9} {:>10} {:>12} {:>13} {:>12}",
        "configuration", "completed", "pipelines", "multi-stage", "mean lat(s)", "energy(J)"
    );
    row("bent pipe", &bent);
    row("single split + isl", &single);
    row("pipeline ≤3 nodes", &piped);

    let stages: Vec<usize> = piped.records.iter().map(|r| r.stages).collect();
    println!(
        "\nper-sat pipeline stages: {:?}; stage counts per request: {:?}",
        piped.per_sat().iter().map(|s| s.pipeline_stages).collect::<Vec<_>>(),
        &stages[..stages.len().min(8)],
    );

    // the acceptance bar: the placement vector must be a *genuine*
    // multi-node win — admitted as pipelines, executed in two stages, and
    // strictly faster than both the bent pipe and the best single split
    anyhow::ensure!(
        piped.completed() == trace.len() as u64
            && bent.completed() == trace.len() as u64
            && single.completed() == trace.len() as u64,
        "every variant must finish the trace"
    );
    anyhow::ensure!(
        piped.pipeline_requests == trace.len() as u64,
        "every capture must be admitted as a multi-node pipeline"
    );
    anyhow::ensure!(
        piped.records.iter().all(|r| r.stages == 2),
        "each capture must run as two stages (cut after layer 0)"
    );
    anyhow::ensure!(
        piped.mean_latency() < single.mean_latency()
            && piped.mean_latency() < bent.mean_latency(),
        "pipeline ({:.2} s) must strictly beat single split ({:.2} s) and bent pipe ({:.2} s)",
        piped.mean_latency().value(),
        single.mean_latency().value(),
        bent.mean_latency().value()
    );
    println!(
        "\nOK: two-stage placement beats the best single split by {:.2} s per capture.",
        single.mean_latency().value() - piped.mean_latency().value()
    );
    Ok(())
}
