//! Trace study: where does the latency of a contact-starved fleet go?
//!
//! ```bash
//! cargo run --release --example trace_study            # full 48 h study
//! cargo run --release --example trace_study -- --smoke # CI-sized run
//! ```
//!
//! A Walker 8/4/1 whose satellites see a ground station for two minutes
//! every three hours: captures finish processing quickly, then sit in
//! the transmitter queue waiting for a pass. The aggregate metrics show
//! the symptom (a brutal P99); the trace shows the *cause*. This study
//! arms the [`leo_infer::obs`] recorder, replays the scenario, folds the
//! captured spans into per-phase totals ([`Trace::phase_totals`]), and
//! asserts the diagnosis: downlink transmission — queueing for a contact
//! window plus the transfer itself — dominates every other phase.
//!
//! The run also writes both exporter formats (`trace_study.jsonl`,
//! `trace_study_chrome.json`), re-validates them through
//! [`leo_infer::obs::validate`], and cross-checks the trace against the
//! metrics: exactly one `Done` mark per completed request. Load the
//! Chrome file in `chrome://tracing` or [Perfetto](https://ui.perfetto.dev)
//! to see the per-satellite tracks — docs/OBSERVABILITY.md walks through
//! the picture.

use leo_infer::config::FleetScenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::obs::{TraceEvent, TraceFormat};
use leo_infer::sim::fleet::FleetSimulator;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let hours = if smoke { 12.0 } else { 48.0 };

    // Walker 8/4/1, contact-starved: a 2-minute pass every 3 hours
    let mut scen = FleetScenario::walker_631();
    scen.name = "walker-8-4-1-starved".to_string();
    scen.sats = 8;
    scen.planes = 4;
    scen.phasing = 1;
    scen.base.t_cyc_hours = 3.0;
    scen.base.t_con_minutes = 2.0;
    scen.horizon_hours = hours;
    scen.interarrival_s = 600.0;
    scen.data_gb_lo = 0.2;
    scen.data_gb_hi = 2.0;
    scen.trace = true;
    scen.trace_sample_every_s = 600.0;

    let mut rng = Pcg64::seeded(0x17ACE);
    let workload = scen.workload()?.generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(10, &mut rng);
    let engine = SolverRegistry::engine("ilpb")?;
    let result = FleetSimulator::new(scen.sim_config(profile)?).run(&workload, &engine)?;
    let m = &result.metrics;
    let trace = result.trace.expect("scenario armed the recorder");

    println!(
        "trace study{}: Walker 8/4/1, {:.0}-min pass every {:.0} h, {} captures over {:.0} h\n",
        if smoke { " (smoke)" } else { "" },
        scen.base.t_con_minutes,
        scen.base.t_cyc_hours,
        workload.len(),
        hours,
    );
    println!(
        "outcome     : {} completed, {} rejected, {} unfinished — mean lat {:.0} s, p99 {:.0} s",
        m.completed(),
        m.rejected(),
        m.unfinished,
        m.mean_latency().value(),
        m.latency_p99().value()
    );

    // fold the spans into per-phase sim-time totals, largest first
    let totals = trace.phase_totals();
    println!("\n{:<14} {:>14} {:>9}", "phase", "sim-time (s)", "share");
    let sum: f64 = totals.iter().map(|(_, t)| t).sum();
    for (phase, t) in &totals {
        println!("{phase:<14} {t:>14.0} {:>8.1}%", 100.0 * t / sum.max(1e-12));
    }
    let (dominant, dominant_s) = totals.first().expect("a run this size records spans");
    println!("\ndominant phase: {dominant} ({dominant_s:.0} s of sim time)");

    // the diagnosis this study exists to assert: transmission — waiting
    // for a contact window plus the transfer — dominates a starved fleet
    anyhow::ensure!(
        dominant == "tx" || dominant == "tx_wait",
        "expected the downlink phase to dominate a contact-starved fleet, got `{dominant}`"
    );
    // trace ↔ metrics cross-check: one Done mark per completed request
    let done = trace.count(|e| matches!(e, TraceEvent::Done { .. }));
    anyhow::ensure!(
        done as u64 == m.completed(),
        "{done} Done marks for {} completions",
        m.completed()
    );
    // the gauge sampler ran: 600 s cadence over the whole horizon
    let gauges = trace.count(|e| matches!(e, TraceEvent::Gauge { .. }));
    anyhow::ensure!(gauges > 0, "gauge sampling was armed but recorded nothing");

    // write both export formats and re-validate them through the same
    // checker CI uses (`leo-infer trace-validate`)
    for (path, format) in [
        ("trace_study.jsonl", TraceFormat::Jsonl),
        ("trace_study_chrome.json", TraceFormat::Chrome),
    ] {
        trace.write(path, format)?;
        let text = std::fs::read_to_string(path)?;
        let (detected, summary) = leo_infer::obs::validate(&text)?;
        anyhow::ensure!(detected == format, "{path}: detected {:?}", detected);
        println!(
            "wrote {path}: {} events ({} spans, {} marks, {} gauges) — schema-valid {}",
            summary.events,
            summary.spans,
            summary.marks,
            summary.gauges,
            format.as_str()
        );
    }

    println!("\nOK: downlink transmission dominates the contact-starved fleet's latency.");
    Ok(())
}
