//! Relay study: ISL offloading vs the paper's bent pipe.
//!
//! ```bash
//! cargo run --release --example relay_study            # full 48 h study
//! cargo run --release --example relay_study -- --smoke # CI-sized run
//! ```
//!
//! A contact-starved Walker 8/4/1 under the paper's Tiansuan cadence: each
//! satellite sees one 6-minute ground pass every 8 hours, staggered an
//! hour apart across the fleet. Captures land round-robin — the capture-
//! bound case where the router cannot shop for a satellite about to pass —
//! so a boundary tensor produced mid-gap waits on average ~4 h for its own
//! satellite's downlink.
//!
//! Inter-satellite links change that arithmetic: with a `grid` topology a
//! satellite's tensor can cross an ISL to whichever neighbor (fore/aft in
//! plane, same slot in the adjacent planes) passes next, cutting the wait
//! to the fleet's pass spacing. The same trace is pushed through three
//! configurations:
//!
//! * `ars · isl off`  — all-on-satellite: no downlink at all, every stage
//!   computed on the (slow) capture satellite;
//! * `ilpb · isl off` — the paper's bent pipe: optimal split, own pass only;
//! * `ilpb · isl grid`— the relay path this study is about.
//!
//! The run asserts the headline result — relays beat both baselines on
//! mean latency — so CI fails if the relay path ever rots.

use leo_infer::config::FleetScenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::link::isl::IslMode;
use leo_infer::sim::fleet::{FleetResult, FleetSimulator};
use leo_infer::sim::workload::Request;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;

fn scenario(smoke: bool) -> FleetScenario {
    let mut scen = FleetScenario::walker_631();
    scen.name = "relay-study-8-4-1".to_string();
    scen.sats = 8;
    scen.planes = 4;
    scen.phasing = 1;
    // capture-bound arrivals: the router cannot chase ground passes
    scen.routing = "round-robin".to_string();
    // optical-class ISL reference rate; per-link rates scale with range
    scen.isl_rate_mbps = 1000.0;
    // modest tensors keep the all-on-satellite baseline stable (≈ 0.1–0.5
    // GB is 3–10 ks of on-board compute at the paper's β)
    scen.data_gb_lo = 0.1;
    scen.data_gb_hi = 0.5;
    if smoke {
        scen.horizon_hours = 12.0;
        scen.interarrival_s = 3600.0;
    } else {
        scen.horizon_hours = 48.0;
        scen.interarrival_s = 1800.0;
    }
    scen
}

fn run(
    scen: &FleetScenario,
    policy: &str,
    isl: IslMode,
    trace: &[Request],
    profile: &ModelProfile,
) -> anyhow::Result<FleetResult> {
    let mut scen = scen.clone();
    scen.isl = isl;
    let engine = SolverRegistry::engine(policy)?;
    FleetSimulator::new(scen.sim_config(profile.clone())?).run(trace, &engine)
}

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scen = scenario(smoke);

    let mut rng = Pcg64::seeded(0x15_1AB);
    let trace = scen.workload().generate(scen.horizon(), &mut rng);
    let profile = ModelProfile::sampled(10, &mut rng);
    println!(
        "relay study{}: Walker {}/{}/{} @ {} km, {} captures ({:.1}-{:.1} GB) over {} h,\n\
         one {:.0}-min pass per satellite every {:.0} h (staggered 1 h apart)\n",
        if smoke { " (smoke)" } else { "" },
        scen.sats,
        scen.planes,
        scen.phasing,
        scen.altitude_km,
        trace.len(),
        scen.data_gb_lo,
        scen.data_gb_hi,
        scen.horizon_hours,
        scen.base.t_con_minutes,
        scen.base.t_cyc_hours,
    );

    let ars = run(&scen, "ars", IslMode::Off, &trace, &profile)?;
    let bent = run(&scen, "ilpb", IslMode::Off, &trace, &profile)?;
    let relay = run(&scen, "ilpb", IslMode::Grid, &trace, &profile)?;

    println!(
        "{:<16} {:>9} {:>11} {:>13} {:>11} {:>7} {:>10}",
        "configuration", "completed", "unfinished", "mean lat(s)", "p50 lat(s)", "relays", "isl(GB)"
    );
    for (name, r) in [
        ("ars · isl off", &ars),
        ("ilpb · isl off", &bent),
        ("ilpb · isl grid", &relay),
    ] {
        let m = &r.metrics;
        println!(
            "{:<16} {:>9} {:>11} {:>13.0} {:>11.0} {:>7} {:>10.2}",
            name,
            m.completed(),
            m.unfinished,
            m.mean_latency().value(),
            m.latency_p50().value(),
            m.relays,
            m.relayed_bytes.gb()
        );
    }

    let relay_mean = relay.metrics.mean_latency().value();
    let bent_mean = bent.metrics.mean_latency().value();
    let ars_mean = ars.metrics.mean_latency().value();
    println!(
        "\nrelay vs bent pipe: {:.0}% of the mean latency; vs all-on-satellite: {:.0}%",
        100.0 * relay_mean / bent_mean,
        100.0 * relay_mean / ars_mean
    );
    println!(
        "{} of {} completed requests crossed an ISL",
        relay
            .metrics
            .records
            .iter()
            .filter(|r| r.relay.is_some())
            .count(),
        relay.metrics.completed()
    );

    // the acceptance bar: relays must beat BOTH baselines on mean latency
    anyhow::ensure!(
        relay.metrics.completed() > 0 && relay.metrics.relays > 0,
        "the contact-starved scenario must actually exercise relays"
    );
    anyhow::ensure!(
        relay_mean < bent_mean,
        "relay ({relay_mean:.0} s) must beat the bent pipe ({bent_mean:.0} s)"
    );
    anyhow::ensure!(
        relay_mean < ars_mean,
        "relay ({relay_mean:.0} s) must beat all-on-satellite ({ars_mean:.0} s)"
    );
    println!("\nOK: ISL relaying dominates both bent-pipe and all-on-satellite baselines.");
    Ok(())
}
