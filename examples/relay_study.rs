//! Relay study: ISL offloading vs the paper's bent pipe — now a thin
//! wrapper over the [`leo_infer::exp`] sweep subsystem.
//!
//! ```bash
//! cargo run --release --example relay_study            # full 48 h study
//! cargo run --release --example relay_study -- --smoke # CI-sized run
//! ```
//!
//! A contact-starved Walker 8/4/1 under the paper's Tiansuan cadence: each
//! satellite sees one 6-minute ground pass every 8 hours, staggered an
//! hour apart across the fleet, over a sparse ground segment (one station
//! worth of contact per satellite). Captures land round-robin — the
//! capture-bound case where the router cannot shop for a satellite about
//! to pass — so a boundary tensor produced mid-gap waits on average ~4 h
//! for its own satellite's downlink.
//!
//! The study is the cross product {ars, ilpb} × {isl off, isl grid} ×
//! {1 hop, 4 hops}, declared as a [`SweepSpec`] and executed by the
//! deterministic parallel runner. Cells sharing a replication share a
//! seed (common random numbers), so every configuration sees the *same*
//! capture trace. The interesting diagonal:
//!
//! * `ars · off`       — all-on-satellite: no downlink at all;
//! * `ilpb · off`      — the paper's bent pipe: optimal split, own pass only;
//! * `ilpb · grid · 1` — PR 3's single-hop relay;
//! * `ilpb · grid · 4` — multi-hop contact-graph routing
//!   ([`leo_infer::link::route`]): the tensor chains across the grid to
//!   whichever satellite passes first.
//!
//! (The grid is a plain cross product, so `isl off` also appears at both
//! hop bounds; the bound is inert without ISLs and those duplicate cells
//! cost pennies at smoke scale — the assertions read the `1`-hop copies.)
//!
//! The run asserts the headline results — single-hop relaying beats both
//! paper baselines, and multi-hop routing *strictly* beats single-hop —
//! so CI fails if either path ever rots.

use leo_infer::config::FleetScenario;
use leo_infer::exp::{self, Axes, CellResult, SweepSpec};
use leo_infer::link::isl::IslMode;

fn spec(smoke: bool) -> SweepSpec {
    let mut base = FleetScenario::walker_631();
    base.name = "relay-study-8-4-1".to_string();
    base.sats = 8;
    base.planes = 4;
    base.phasing = 1;
    // capture-bound arrivals: the router cannot chase ground passes
    base.routing = "round-robin".to_string();
    // optical-class ISL reference rate; per-link rates scale with range
    base.isl_rate_mbps = 1000.0;
    // modest tensors keep the all-on-satellite baseline stable (≈ 0.1–0.5
    // GB is 3–10 ks of on-board compute at the paper's β)
    base.data_gb_lo = 0.1;
    base.data_gb_hi = 0.5;
    if smoke {
        base.horizon_hours = 12.0;
        base.interarrival_s = 3600.0;
    } else {
        base.horizon_hours = 48.0;
        base.interarrival_s = 1800.0;
    }
    SweepSpec {
        name: "relay-study".to_string(),
        seed: 0x15_1AB,
        replications: 1,
        base,
        axes: Axes {
            solver: vec!["ars".to_string(), "ilpb".to_string()],
            isl: vec![IslMode::Off, IslMode::Grid],
            route: vec![1, 4],
            ..Axes::default()
        },
    }
}

/// The cell for a (solver, isl, max-hops) coordinate.
fn pick<'a>(cells: &'a [CellResult], solver: &str, isl: IslMode, hops: usize) -> &'a CellResult {
    cells
        .iter()
        .find(|c| {
            c.cell.solver == solver
                && c.cell.scenario.isl == isl
                && c.cell.scenario.isl_max_hops == hops
        })
        .expect("configuration in grid")
}

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = spec(smoke);
    let scen = &spec.base;
    println!(
        "relay study{}: Walker {}/{}/{} @ {} km, {:.1}-{:.1} GB captures over {} h,\n\
         one {:.0}-min pass per satellite every {:.0} h (staggered 1 h apart)\n\
         grid: {} cells over solver x isl x max-hops, common random numbers per replication\n",
        if smoke { " (smoke)" } else { "" },
        scen.sats,
        scen.planes,
        scen.phasing,
        scen.altitude_km,
        scen.data_gb_lo,
        scen.data_gb_hi,
        scen.horizon_hours,
        scen.base.t_con_minutes,
        scen.base.t_cyc_hours,
        spec.len(),
    );

    let result = exp::run_sweep(&spec, exp::default_threads())?;

    println!(
        "{:<20} {:>9} {:>11} {:>13} {:>11} {:>10} {:>7} {:>9} {:>10}",
        "configuration", "completed", "unfinished", "mean lat(s)", "p50 lat(s)", "p95 lat(s)",
        "relays", "reroutes", "isl(GB)"
    );
    for c in &result.cells {
        println!(
            "{:<20} {:>9} {:>11} {:>13.0} {:>11.0} {:>10.0} {:>7} {:>9} {:>10.2}",
            format!(
                "{} · isl {} · ≤{}h",
                c.cell.solver,
                c.cell.scenario.isl.as_str(),
                c.cell.scenario.isl_max_hops
            ),
            c.completed,
            c.unfinished,
            c.mean_latency_s(),
            c.p50_latency_s(),
            c.p95_latency_s(),
            c.relays,
            c.route_recomputes,
            c.relayed_gb
        );
    }
    println!("\nby isl mode:");
    print!("{}", exp::comparison_table(&result, "isl")?);
    println!("by max hops:");
    print!("{}", exp::comparison_table(&result, "route")?);

    let ars = pick(&result.cells, "ars", IslMode::Off, 1);
    let bent = pick(&result.cells, "ilpb", IslMode::Off, 1);
    let single = pick(&result.cells, "ilpb", IslMode::Grid, 1);
    let multi = pick(&result.cells, "ilpb", IslMode::Grid, 4);
    println!(
        "\nsingle-hop vs bent pipe: {:.0}% of the mean latency; \
         multi-hop vs single-hop: {:.0}%",
        100.0 * single.mean_latency_s() / bent.mean_latency_s(),
        100.0 * multi.mean_latency_s() / single.mean_latency_s()
    );

    // the acceptance bar, part 1 (PR 3): single-hop relaying must beat
    // BOTH paper baselines on mean latency
    anyhow::ensure!(
        single.completed > 0 && single.relays > 0,
        "the contact-starved scenario must actually exercise relays"
    );
    anyhow::ensure!(
        single.mean_latency_s() < bent.mean_latency_s(),
        "single-hop relay ({:.0} s) must beat the bent pipe ({:.0} s)",
        single.mean_latency_s(),
        bent.mean_latency_s()
    );
    anyhow::ensure!(
        single.mean_latency_s() < ars.mean_latency_s(),
        "single-hop relay ({:.0} s) must beat all-on-satellite ({:.0} s)",
        single.mean_latency_s(),
        ars.mean_latency_s()
    );
    // part 2 (this PR): multi-hop contact-graph routing must *strictly*
    // beat the single-hop relay in the sparse-ground-station fleet — only
    // 3 of the 7 other satellites are one hop away, so the chain reaches
    // passes the single hop cannot
    anyhow::ensure!(
        multi.mean_latency_s() < single.mean_latency_s(),
        "multi-hop ({:.0} s) must strictly beat single-hop ({:.0} s)",
        multi.mean_latency_s(),
        single.mean_latency_s()
    );
    println!(
        "\nOK: relays dominate both paper baselines, and multi-hop routing \
         strictly beats single-hop."
    );
    Ok(())
}
