//! End-to-end serving driver: the full three-layer stack on real compute.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```
//!
//! Loads the AOT-compiled RSNet stages (L2 jax → HLO text, L1 Pallas
//! kernels inside) onto **two PJRT CPU clients** — one standing for the
//! satellite payload, one for the cloud DC — and serves batched inference
//! requests through the coordinator: admission → routing → dynamic
//! batching → ILPB split decision → prefix stages on the satellite client
//! → boundary activation serialized (the downlink payload, byte-counted)
//! → suffix stages on the cloud client → classifications.
//!
//! Reports per-batch latency, measured downlink bytes vs the raw-capture
//! baseline, and throughput. Recorded in EXPERIMENTS.md §E2E.

use leo_infer::coordinator::admission::AdmissionController;
use leo_infer::coordinator::batcher::BatchPolicy;
use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::coordinator::scheduler::Scheduler;
use leo_infer::coordinator::server::{ExecutorFactory, Server, ServerConfig, StageExecutor};
use leo_infer::link::downlink::DownlinkModel;
use leo_infer::runtime::artifacts::Manifest;
use leo_infer::runtime::pjrt::StageRuntime;
use leo_infer::runtime::split::SplitExecutor;
use leo_infer::sim::workload::Request;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds};
use std::time::Instant;

const BATCH: usize = 8;
const REQUESTS: u64 = 64;

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    let manifest = Manifest::load("artifacts").map_err(|e| {
        anyhow::anyhow!("{e}\n(hint: run `make artifacts` first)")
    })?;
    println!(
        "loaded manifest: {} — {} stages, batch sizes {:?}",
        manifest.model,
        manifest.depth(),
        manifest.batch_sizes
    );

    // the solver consumes the MEASURED activation profile from the
    // artifacts themselves — no analytic approximation on the e2e path
    let profile = manifest.measured_profile(BATCH)?;
    let scenario = leo_infer::config::Scenario::tiansuan();
    let scheduler = Scheduler::new(
        scenario.instance_builder(profile.clone()),
        vec![profile],
        SolverRegistry::engine("ilpb")?,
    );

    let config = ServerConfig {
        routing: RoutingPolicy::RoundRobin,
        batching: BatchPolicy {
            max_batch: BATCH,
            max_wait: Seconds(0.5),
            expedite_critical: true,
        },
        admission: AdmissionController::default(),
        downlink: DownlinkModel::new(
            BitsPerSec::from_mbps(scenario.rate_mbps),
            Seconds::from_hours(scenario.t_cyc_hours),
            Seconds::from_minutes(scenario.t_con_minutes),
        ),
    };

    // one satellite worker; its executor (two PJRT clients) is built
    // inside the worker thread — PJRT clients are thread-affine
    let m2 = Manifest::load("artifacts")?;
    let factory: ExecutorFactory = Box::new(move || {
        let sat = StageRuntime::load("satellite", &m2, BATCH)?;
        let cloud = StageRuntime::load("cloud", &m2, BATCH)?;
        Ok(Box::new(SplitExecutor::new(sat, cloud)?) as Box<dyn StageExecutor>)
    });
    let mut server = Server::new(config, scheduler, vec![factory]);

    // submit a burst of captures (8 MB synthetic tiles per request in the
    // decision model; the physical tensors are 3x64x64 f32)
    println!("submitting {REQUESTS} requests (batch {BATCH})...");
    let t0 = Instant::now();
    for id in 0..REQUESTS {
        let req = Request {
            id,
            arrival: Seconds(t0.elapsed().as_secs_f64()),
            data: Bytes::from_mb(8.0),
            model: 0,
            class: 0,
        };
        server.submit(req, Seconds(t0.elapsed().as_secs_f64()))?;
    }
    let completions = server.shutdown(Seconds(t0.elapsed().as_secs_f64() + 1.0))?;
    let wall = t0.elapsed().as_secs_f64();

    // report
    let mut served = 0usize;
    let mut onboard = 0.0;
    let mut cloud = 0.0;
    let mut modelled_downlink = 0.0;
    let mut payload_bytes = 0.0;
    let mut raw_bytes = 0.0;
    let mut class_hist = [0usize; 10];
    for c in &completions {
        served += c.plan.batch.len();
        onboard += c.report.onboard_s;
        cloud += c.report.cloud_s;
        modelled_downlink += c.report.downlink_s;
        payload_bytes += c.plan.downlink_bytes.value();
        raw_bytes += c
            .plan
            .batch
            .requests
            .iter()
            .map(|r| r.data.value())
            .sum::<f64>();
        for &cls in &c.report.outputs {
            class_hist[cls.min(9)] += 1;
        }
    }
    println!("\n== e2e results ==");
    println!("served             : {served}/{REQUESTS} requests in {} batches", completions.len());
    println!("wall time          : {wall:.2} s ({:.1} req/s)", served as f64 / wall);
    println!("split chosen       : {} of {} stages on the satellite",
        completions.first().map(|c| c.plan.split).unwrap_or(0), manifest.depth());
    println!("onboard compute    : {onboard:.3} s total");
    println!("cloud compute      : {cloud:.3} s total");
    println!("modelled downlink  : {modelled_downlink:.1} s (Eq. 3, 8 h contact cadence)");
    println!(
        "downlink payload   : {:.2} MB vs {:.2} MB raw ({:.1}% of bent-pipe)",
        payload_bytes / 1e6,
        raw_bytes / 1e6,
        100.0 * payload_bytes / raw_bytes
    );
    println!("class histogram    : {class_hist:?}");

    anyhow::ensure!(served as u64 == REQUESTS, "lost requests");

    // ---- physical split sweep -------------------------------------------
    // Execute one batch through EVERY interesting split boundary to show
    // the prefix/wire/suffix mechanics and the real payload sizes. (The
    // optimizer's choice above is scenario-dependent; this sweep is the
    // system demonstration.)
    println!("\n== physical split sweep (batch of {BATCH}) ==");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>10}",
        "split", "onboard (ms)", "wire (bytes)", "cloud (ms)", "agree"
    );
    let m3 = Manifest::load("artifacts")?;
    let sat = StageRuntime::load("satellite", &m3, BATCH)?;
    let cloud = StageRuntime::load("cloud", &m3, BATCH)?;
    let exec = SplitExecutor::new(sat, cloud)?;
    let input = leo_infer::runtime::tensor::HostTensor::random(
        vec![BATCH, 3, 64, 64],
        0xE2E,
    );
    let (reference, _, _, _) = exec.run_split(input.clone(), 0)?;
    for split in [0usize, 3, 6, 9, 12, 15] {
        let (out, sat_s, wire, cloud_s) = exec.run_split(input.clone(), split)?;
        let agree = out.data == reference.data;
        println!(
            "{:>6} {:>14.2} {:>14} {:>14.2} {:>10}",
            split,
            sat_s * 1e3,
            wire,
            cloud_s * 1e3,
            if agree { "bitexact" } else { "DIVERGED" }
        );
        anyhow::ensure!(agree, "split {split} diverged from reference");
    }

    println!("\nOK — full stack (coordinator → PJRT satellite client → wire → PJRT cloud client) verified.");
    Ok(())
}
