//! Quickstart: solve one offloading decision through the engine API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's Tiansuan scenario, profiles a DNN, constructs a
//! solver **by registry name**, and solves a [`SolveRequest`] — once cold,
//! once telemetry-constrained, once from the decision cache — then
//! compares against the ARG / ARS baselines.

use leo_infer::config::Scenario;
use leo_infer::dnn::{models, profile::ModelProfile};
use leo_infer::solver::{SolveRequest, SolverRegistry, Telemetry};
use leo_infer::util::units::{Bytes, Seconds};

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    // 1. Scenario: the paper's §V-A setting (500 km LEO, 8 h contact
    //    period, 6 min contacts, mid-range link and power parameters).
    let scenario = Scenario::tiansuan();

    // 2. Model: the paper's sampled per-layer profile (α_k ∈ [0.05^k,
    //    0.9^k], K = 10). Real architectures from the zoo work too —
    //    see `models::vgg16()` etc. and `leo-infer models`.
    let net = models::vgg16();
    println!(
        "zoo check: {} — {} subtasks, {:.1}M params, {:.1} GFLOPs",
        net.name,
        net.depth(),
        net.total_params()? as f64 / 1e6,
        net.total_flops()? as f64 / 1e9,
    );
    let mut rng = leo_infer::util::rng::Pcg64::seeded(3);
    let profile = ModelProfile::sampled(10, &mut rng);
    println!("profile: {} (the paper's synthetic draw)", profile.name);

    // 3. One heavy 500 GB capture over a congested 10 Mbps pass — the
    //    regime where neither bent-pipe nor all-onboard is good and the
    //    split decision actually matters.
    let scenario = scenario.with_rate_mbps(10.0);
    let inst = scenario
        .instance_builder(profile)
        .data(Bytes::from_gb(500.0))
        .build()?;

    // 4. Pick the paper's algorithm by registry name and solve.
    let engine = SolverRegistry::engine("ilpb")?;
    let outcome = engine.solve(&SolveRequest::new(inst.clone()));
    let d = &outcome.decision;
    println!(
        "\n{}: split after subtask {} of {} (Z = {:.4}, solved in {:.2} ms)",
        outcome.solver,
        d.split,
        inst.depth(),
        d.z,
        outcome.wall_s * 1e3,
    );
    println!(
        "  latency {:>12.1} s  = sat {:.1} + downlink {:.1} + wan {:.1} + cloud {:.1}",
        d.costs.latency.value(),
        d.costs.t_satellite.value(),
        d.costs.t_downlink.value(),
        d.costs.t_ground_cloud.value(),
        d.costs.t_cloud.value(),
    );
    println!(
        "  energy  {:>12.1} J  = processing {:.1} + transmission {:.1}",
        d.costs.energy.value(),
        d.costs.e_processing.value(),
        d.costs.e_transmission.value(),
    );

    // 5. The same request with live telemetry: 90 seconds of contact
    //    window left means a big boundary activation cannot move — the
    //    engine tightens the feasible splits before accepting the answer.
    let constrained = engine.solve(
        &SolveRequest::new(inst.clone())
            .with_telemetry(Telemetry::unconstrained().with_contact_remaining(Seconds(90.0))),
    );
    println!(
        "\nwith 90 s of window left: split {} (tightened: {})",
        constrained.decision.split, constrained.tightened,
    );

    // 6. Repeat the original request: the decision cache answers it.
    let replay = engine.solve(&SolveRequest::new(inst.clone()));
    println!(
        "replayed request: cached = {}, identical split {} (engine: {} solves, {} hits)",
        replay.cached,
        replay.decision.split,
        engine.stats().solves,
        engine.stats().cache_hits,
    );

    // 7. The paper's baselines, also by registry name.
    for name in ["arg", "ars"] {
        let baseline = SolverRegistry::engine(name)?;
        let out = baseline.solve(&SolveRequest::new(inst.clone()));
        println!(
            "\n{:<4}: split {} — Z = {:.4}, latency {:.1} s, energy {:.1} J",
            out.solver,
            out.decision.split,
            out.decision.z,
            out.decision.costs.latency.value(),
            out.decision.costs.energy.value(),
        );
    }
    Ok(())
}
