//! Quickstart: solve one offloading decision and print it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's Tiansuan scenario, profiles VGG-16 analytically,
//! solves the ILP with the ILPB branch-and-bound, and compares against the
//! ARG / ARS baselines.

use leo_infer::config::Scenario;
use leo_infer::dnn::{models, profile::ModelProfile};
use leo_infer::solver::{Arg, Ars, Ilpb, OffloadPolicy};
use leo_infer::util::units::Bytes;

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    // 1. Scenario: the paper's §V-A setting (500 km LEO, 8 h contact
    //    period, 6 min contacts, mid-range link and power parameters).
    let scenario = Scenario::tiansuan();

    // 2. Model: the paper's sampled per-layer profile (α_k ∈ [0.05^k,
    //    0.9^k], K = 10). Real architectures from the zoo work too —
    //    see `models::vgg16()` etc. and `leo-infer models`.
    let net = models::vgg16();
    println!(
        "zoo check: {} — {} subtasks, {:.1}M params, {:.1} GFLOPs",
        net.name,
        net.depth(),
        net.total_params()? as f64 / 1e6,
        net.total_flops()? as f64 / 1e9,
    );
    let mut rng = leo_infer::util::rng::Pcg64::seeded(3);
    let profile = ModelProfile::sampled(10, &mut rng);
    println!("profile: {} (the paper's synthetic draw)", profile.name);

    // 3. One heavy 500 GB capture over a congested 10 Mbps pass — the
    //    regime where neither bent-pipe nor all-onboard is good and the
    //    split decision actually matters.
    let scenario = scenario.with_rate_mbps(10.0);
    let inst = scenario
        .instance_builder(profile)
        .data(Bytes::from_gb(500.0))
        .build()?;

    // 4. Solve with the paper's algorithm and both baselines.
    let (decision, stats) = Ilpb::default().solve(&inst);
    println!(
        "\nILPB: split after subtask {} of {} (Z = {:.4})",
        decision.split,
        inst.depth(),
        decision.z
    );
    println!(
        "  search: {} nodes, {} leaves, {} pruned",
        stats.nodes, stats.leaves, stats.pruned
    );
    println!(
        "  latency {:>12.1} s  = sat {:.1} + downlink {:.1} + wan {:.1} + cloud {:.1}",
        decision.costs.latency.value(),
        decision.costs.t_satellite.value(),
        decision.costs.t_downlink.value(),
        decision.costs.t_ground_cloud.value(),
        decision.costs.t_cloud.value(),
    );
    println!(
        "  energy  {:>12.1} J  = processing {:.1} + transmission {:.1}",
        decision.costs.energy.value(),
        decision.costs.e_processing.value(),
        decision.costs.e_transmission.value(),
    );

    for policy in [&Arg as &dyn OffloadPolicy, &Ars] {
        let d = policy.decide(&inst);
        println!(
            "\n{:<4}: split {} — Z = {:.4}, latency {:.1} s, energy {:.1} J",
            policy.name(),
            d.split,
            d.z,
            d.costs.latency.value(),
            d.costs.energy.value(),
        );
    }
    Ok(())
}
