//! Terrain survey: the paper's energy-constrained long-duration workload.
//!
//! ```bash
//! cargo run --release --example terrain_survey
//! ```
//!
//! Remote-sensing of terrain/geomorphic change has no tight deadline, but
//! the satellite lives on a ~15 W-peak solar panel and an 80 Wh battery:
//! the objective weight is energy-heavy (μ = 0.9). We run a week of
//! captures against a battery+solar model with the DoD floor enforced and
//! watch which algorithms keep the payload alive.

use leo_infer::config::Scenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::energy::battery::Battery;
use leo_infer::energy::solar::SolarPanel;
use leo_infer::orbit::propagator::CircularOrbit;
use leo_infer::orbit::eclipse::eclipse_fraction;
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::entities::SatelliteState;
use leo_infer::sim::runner::{SimConfig, Simulator};
use leo_infer::sim::workload::{PoissonWorkload, SizeDist};
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{Bytes, Joules, Seconds};

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    // energy-heavy weighting on the transmission-dominant platform: an
    // efficient accelerator against a power-hungry antenna (see
    // Scenario::transmission_dominant docs) — the regime where computing
    // on board to shrink the downlink genuinely saves battery.
    let scenario = Scenario::transmission_dominant().with_weights(0.9, 0.1);

    // physical energy budget from the orbit substrate
    let orbit = CircularOrbit::new(500.0, 97.4, 0.0, 0.0);
    let sunlit = 1.0 - eclipse_fraction(&orbit);
    let panel = SolarPanel::cubesat_6u();
    println!(
        "orbit: 500 km SSO — {:.0}% sunlit, {:.1} W harvest while lit",
        sunlit * 100.0,
        panel.sunlit_power().value()
    );

    let workload = PoissonWorkload::new(
        1.0 / 3600.0, // hourly captures
        SizeDist::Uniform(Bytes::from_gb(1.0), Bytes::from_gb(4.0)),
    );
    // one week of captures; the sim horizon is far larger so the queued
    // tail drains rather than being cut off as unfinished (the horizon
    // is enforced by the DES) — served + rejected stays accountable
    let capture_window = Seconds::from_hours(168.0);
    let horizon = Seconds::from_hours(100_000.0);
    let mut rng = Pcg64::seeded(0x7E44);
    let trace = workload.generate(capture_window, &mut rng);
    let profile = ModelProfile::sampled(scenario.depth, &mut rng);
    println!(
        "survey: {} captures over {:.0} h (λ:μ = 0.1:0.9), 80 Wh battery, 20% DoD floor\n",
        trace.len(),
        capture_window.hours()
    );

    println!(
        "{:<6} {:>8} {:>9} {:>12} {:>12} {:>10}",
        "algo", "served", "rejected", "energy(J)", "final SoC", "mean lat(s)"
    );
    for name in ["ilpb", "arg", "ars"] {
        let engine = SolverRegistry::engine(name)?;
        let config = SimConfig {
            template: scenario.instance_builder(profile.clone()),
            profiles: vec![profile.clone()],
            contact: PeriodicContact::new(
                Seconds::from_hours(scenario.t_cyc_hours),
                Seconds::from_minutes(scenario.t_con_minutes),
            ),
            horizon,
        };
        let sat = SatelliteState::new().with_battery(
            Battery::new(Joules(80.0 * 3600.0), 0.2),
            panel,
            sunlit,
        );
        let result = Simulator::new(config).with_satellite(sat).run(&trace, &engine)?;
        let m = &result.metrics;
        println!(
            "{:<6} {:>8} {:>9} {:>12.1} {:>11.1}% {:>10.1}",
            engine.policy_name(),
            m.completed(),
            m.rejected(),
            result.state.energy_drawn.value(),
            result.state.soc() * 100.0,
            m.mean_latency().value(),
        );
    }

    println!(
        "\nUnder an energy-heavy objective ILPB sheds the expensive work \
         (late-layer compute or raw-capture downlink, whichever the battery \
         can least afford) and keeps the duty cycle sustainable."
    );
    Ok(())
}
