//! Placement study: cache-aware routing over a storage-constrained,
//! multi-model fleet vs the cache-oblivious baseline.
//!
//! ```bash
//! cargo run --release --example placement_study            # full 96 h study
//! cargo run --release --example placement_study -- --smoke # CI-sized run
//! ```
//!
//! Four satellites, three DNN models of ~200 MB each, and a 250 MB
//! per-satellite artifact store: no satellite can hold more than one
//! model, so *where* a request lands decides whether its weights are
//! already on board or must first cross the 10 Mbps ground uplink
//! (~168 s per miss). Captures arrive Poisson with Zipf-skewed model
//! popularity ([`PoissonWorkload::with_models`]) — the regime the
//! demand-driven placement layer ([`leo_infer::placement`]) is built for.
//!
//! Three runs over the *same* trace:
//!
//! * `demand · least-loaded` — cache-aware: the router folds each
//!   satellite's weight-miss penalty into its score, so requests follow
//!   the models. After the cold start the fleet converges to a stable
//!   model-per-satellite assignment and stops fetching.
//! * `demand · round-robin`  — cache-oblivious ablation: same stores,
//!   same budget, but the router cycles blindly; satellites thrash the
//!   one-model budget and re-fetch weights continuously.
//! * `everywhere · unlimited` — the passive reference: every model
//!   everywhere, zero fetches (bit-identical to a pre-placement fleet).
//!
//! The run asserts the headline result — cache-aware placement strictly
//! beats cache-oblivious routing on mean latency, with strictly fewer
//! weight fetches — so CI fails if the penalty plumbing ever rots.

use leo_infer::coordinator::router::RoutingPolicy;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::placement::{EvictionPolicy, ModelArtifact, PlacementConfig, PlacementPolicy};
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::fleet::{FleetSimConfig, FleetSimulator, SatelliteSpec, TelemetryMode};
use leo_infer::sim::workload::{PoissonWorkload, Request, SizeDist};
use leo_infer::sim::SimMetrics;
use leo_infer::solver::instance::InstanceBuilder;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{BitsPerSec, Bytes, Seconds};

const SATS: usize = 4;
const WEIGHTS_MB: f64 = 200.0;
const BUDGET_MB: f64 = 250.0;

/// Three models with distinct layer shapes (distinct solve instances).
fn models() -> Vec<ModelProfile> {
    vec![
        ModelProfile::from_alphas("wide-net", &[1000.0, 400.0, 150.0, 40.0, 8.0]).unwrap(),
        ModelProfile::from_alphas("deep-net", &[800.0, 500.0, 300.0, 150.0, 60.0, 10.0]).unwrap(),
        ModelProfile::from_alphas("lite-net", &[600.0, 200.0, 50.0, 5.0]).unwrap(),
    ]
}

/// The ~200 MB-per-model artifact catalog every run shares.
fn catalog() -> Vec<ModelArtifact> {
    models()
        .iter()
        .enumerate()
        .map(|(i, p)| ModelArtifact::from_profile(i, p, Bytes::from_mb(WEIGHTS_MB)))
        .collect()
}

/// Demand placement under the one-model-per-satellite budget.
fn constrained() -> PlacementConfig {
    PlacementConfig {
        policy: PlacementPolicy::Demand,
        eviction: EvictionPolicy::Lru,
        budget: Some(Bytes::from_mb(BUDGET_MB)),
        artifacts: catalog(),
    }
}

fn fleet(routing: RoutingPolicy, placement: PlacementConfig) -> FleetSimConfig {
    let profiles = models();
    // 10 Mbps ground link: a 200 MB weight fetch costs ~168 s, the same
    // order as one request's on-board compute — misses are visible
    let template = InstanceBuilder::new(profiles[0].clone())
        .rate(BitsPerSec::from_mbps(10.0))
        .contact(Seconds::from_hours(8.0), Seconds::from_minutes(6.0));
    FleetSimConfig {
        template,
        profiles,
        sats: (0..SATS)
            .map(|i| {
                let contact =
                    PeriodicContact::new(Seconds::from_hours(8.0), Seconds::from_minutes(6.0))
                        .with_phase(Seconds(i as f64 * 7200.0));
                SatelliteSpec::new(&format!("sat-{i}"), Box::new(contact))
            })
            .collect(),
        routing,
        isl: None,
        isl_max_hops: 0,
        telemetry: TelemetryMode::Live,
        placement,
        route_cache: true,
        timing: false,
        // the study doubles as CI's audit-enabled fleet scenario: it
        // exercises stores, evictions, and pins under real contention
        audit: true,
        trace: None,
        pipeline: None,
        horizon: Seconds::from_hours(100_000.0),
    }
}

fn run(
    routing: RoutingPolicy,
    placement: PlacementConfig,
    trace: &[Request],
) -> anyhow::Result<SimMetrics> {
    // ARS keeps every request fully on board: latency is queueing +
    // weight fetch + compute, with no downlink-window noise between runs
    let engine = SolverRegistry::engine("ars")?;
    let result = FleetSimulator::new(fleet(routing, placement)).run(trace, &engine)?;
    Ok(result.metrics)
}

fn row(label: &str, m: &SimMetrics) {
    let looked_up = m.artifact_hits + m.artifact_misses;
    let warm = if looked_up > 0 {
        100.0 * m.artifact_hits as f64 / looked_up as f64
    } else {
        100.0
    };
    println!(
        "{:<24} {:>9} {:>7} {:>8} {:>7.1}% {:>9} {:>11.2} {:>13.0} {:>10.0}",
        label,
        m.completed(),
        m.artifact_hits,
        m.artifact_misses,
        warm,
        m.evictions,
        m.weight_bytes_in.gb(),
        m.mean_latency().value(),
        m.latency_p95().value(),
    );
}

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let hours = if smoke { 24.0 } else { 96.0 };
    let mut rng = Pcg64::seeded(0xCAC4E);
    let trace = PoissonWorkload::new(
        1.0 / 600.0,
        SizeDist::LogUniform(Bytes::from_mb(5.0), Bytes::from_mb(20.0)),
    )
    .with_models(models().len())
    .generate(Seconds::from_hours(hours), &mut rng);
    println!(
        "placement study{}: {} satellites, {} models x {:.0} MB weights, {:.0} MB stores,\n\
         {} Zipf-skewed captures over {:.0} h — every run replays the same trace\n",
        if smoke { " (smoke)" } else { "" },
        SATS,
        models().len(),
        WEIGHTS_MB,
        BUDGET_MB,
        trace.len(),
        hours,
    );

    let aware = run(RoutingPolicy::LeastLoaded, constrained(), &trace)?;
    let oblivious = run(RoutingPolicy::RoundRobin, constrained(), &trace)?;
    let passive = run(RoutingPolicy::LeastLoaded, PlacementConfig::default(), &trace)?;

    println!(
        "{:<24} {:>9} {:>7} {:>8} {:>8} {:>9} {:>11} {:>13} {:>10}",
        "configuration", "completed", "hits", "misses", "warm", "evictions", "weights(GB)",
        "mean lat(s)", "p95(s)"
    );
    row("demand · least-loaded", &aware);
    row("demand · round-robin", &oblivious);
    row("everywhere · unlimited", &passive);

    // every run drains the whole trace (no batteries, generous horizon)
    for (label, m) in [("aware", &aware), ("oblivious", &oblivious), ("passive", &passive)] {
        anyhow::ensure!(
            m.completed() as usize == trace.len(),
            "{label}: {} of {} requests completed",
            m.completed(),
            trace.len()
        );
    }
    // the passive reference never touches the placement machinery
    anyhow::ensure!(passive.artifact_hits == 0 && passive.artifact_misses == 0);
    // constrained runs consult the store once per admitted request
    anyhow::ensure!(aware.artifact_hits + aware.artifact_misses == aware.completed());
    // the oblivious router thrashes the one-model budget...
    anyhow::ensure!(
        oblivious.evictions > 0 && oblivious.artifact_misses > aware.artifact_misses,
        "round-robin must thrash: {} evictions, {} misses vs {} cache-aware misses",
        oblivious.evictions,
        oblivious.artifact_misses,
        aware.artifact_misses
    );
    // ...and the acceptance bar: cache-aware demand placement strictly
    // beats cache-oblivious routing on mean latency
    anyhow::ensure!(
        aware.mean_latency().value() < oblivious.mean_latency().value(),
        "cache-aware ({:.0} s) must strictly beat cache-oblivious ({:.0} s)",
        aware.mean_latency().value(),
        oblivious.mean_latency().value()
    );
    println!(
        "\ncache-aware vs cache-oblivious: {:.0}% of the mean latency, {} vs {} weight fetches",
        100.0 * aware.mean_latency().value() / oblivious.mean_latency().value(),
        aware.artifact_misses,
        oblivious.artifact_misses
    );
    println!("\nOK: cache-aware demand placement strictly beats cache-oblivious routing.");
    Ok(())
}
