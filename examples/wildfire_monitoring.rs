//! Wildfire monitoring: the paper's latency-critical motivating workload.
//!
//! ```bash
//! cargo run --release --example wildfire_monitoring
//! ```
//!
//! A fire-detection constellation must flag hotspots fast: the objective
//! weight is latency-heavy (λ = 0.9). We simulate 48 h of Poisson capture
//! traffic (20% latency-critical alerts) through the discrete-event
//! simulator under the three algorithms and report end-to-end latency
//! percentiles plus on-board energy — showing why neither bent-pipe (ARG)
//! nor all-onboard (ARS) is deployable, and what ILPB buys.

use leo_infer::config::Scenario;
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::sim::contact::PeriodicContact;
use leo_infer::sim::runner::{SimConfig, Simulator};
use leo_infer::sim::workload::{PoissonWorkload, SizeDist};
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{Bytes, Seconds};

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    // latency-critical weighting: fires spread faster than batteries
    // drain. The link is a congested 12 Mbps share of the pass — heavy
    // scenes cannot all go down raw.
    let scenario = Scenario::tiansuan()
        .with_weights(0.1, 0.9)
        .with_rate_mbps(12.0);

    // wide-area multispectral scenes, 5–80 GB per capture
    let workload = PoissonWorkload::new(
        1.0 / 1800.0, // one capture every ~30 min
        SizeDist::LogUniform(Bytes::from_gb(5.0), Bytes::from_gb(80.0)),
    )
    .with_critical_fraction(0.2);
    // captures arrive over 48 h; the sim horizon is far larger so the
    // transmit-bound backlog drains instead of being cut off as
    // unfinished (the horizon is enforced by the DES)
    let capture_window = Seconds::from_hours(48.0);
    let horizon = Seconds::from_hours(100_000.0);
    let mut rng = Pcg64::seeded(0xF15E);
    let trace = workload.generate(capture_window, &mut rng);
    println!(
        "wildfire watch: {} captures over {:.0} h (λ:μ = 0.9:0.1)\n",
        trace.len(),
        capture_window.hours()
    );

    let profile = ModelProfile::sampled(scenario.depth, &mut rng);
    println!(
        "{:<6} {:>10} {:>12} {:>12} {:>12} {:>14}",
        "algo", "served", "mean lat(s)", "p99 lat(s)", "energy(J)", "downlinked(GB)"
    );
    for name in ["ilpb", "arg", "ars"] {
        let engine = SolverRegistry::engine(name)?;
        let config = SimConfig {
            template: scenario.instance_builder(profile.clone()),
            profiles: vec![profile.clone()],
            contact: PeriodicContact::new(
                Seconds::from_hours(scenario.t_cyc_hours),
                Seconds::from_minutes(scenario.t_con_minutes),
            ),
            horizon,
        };
        let result = Simulator::new(config).run(&trace, &engine)?;
        let m = &result.metrics;
        println!(
            "{:<6} {:>10} {:>12.1} {:>12.1} {:>12.1} {:>14.2}",
            engine.policy_name(),
            m.completed(),
            m.mean_latency().value(),
            m.latency_p99().value(),
            result.state.energy_drawn.value(),
            m.total_downlinked.gb(),
        );
    }

    println!(
        "\nILPB keeps alert latency near the ARG (ground-inference) floor while \
         downlinking a fraction of the bytes — the contact windows stop being \
         the bottleneck."
    );
    Ok(())
}
