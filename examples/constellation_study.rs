//! Constellation study: orbit-derived contact windows + fleet DES — now a
//! thin wrapper over the [`leo_infer::exp`] sweep subsystem.
//!
//! ```bash
//! cargo run --release --example constellation_study
//! ```
//!
//! The paper takes `t_cyc`/`t_con` as given constants and evaluates one
//! satellite in closed form. Here we *derive* per-satellite contact
//! windows from first-principles orbital geometry for a Walker 6/3/1
//! constellation over a real ground-station site, then run the fleet
//! discrete-event simulator end-to-end on them. The routing-policy
//! comparison is a one-axis [`SweepSpec`] executed by the parallel
//! runner: cells share a replication seed, so every policy is scored on
//! the *same* capture trace (common random numbers), exactly like the
//! old hand-rolled loop — minus the loop.

use leo_infer::config::{ContactSource, FleetScenario};
use leo_infer::exp::{self, Axes, SweepSpec};
use leo_infer::orbit::contact::ContactSchedule;
use leo_infer::orbit::eclipse::eclipse_fraction;
use leo_infer::util::units::Seconds;

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    let mut scenario = FleetScenario::walker_631();
    scenario.contact_source = ContactSource::Orbit;
    scenario.horizon_hours = 24.0;
    scenario.interarrival_s = 900.0;
    scenario.data_gb_lo = 0.1;
    scenario.data_gb_hi = 2.0;

    let constellation = scenario.pattern()?.build();
    let gs = scenario.ground_station();
    println!(
        "constellation: {} satellites in {} planes @ {} km over {}",
        scenario.sats, scenario.planes, scenario.altitude_km, gs.name
    );

    // per-satellite geometry over the scenario horizon
    println!(
        "\n{:<10} {:>8} {:>12} {:>12} {:>10}",
        "sat", "passes", "t_con(min)", "t_cyc(h)", "eclipse%"
    );
    for sat in &constellation.satellites {
        let sched = ContactSchedule::compute(
            &sat.orbit,
            &gs,
            scenario.horizon_hours * 3600.0,
            30.0,
        );
        println!(
            "{:<10} {:>8} {:>12.1} {:>12.2} {:>10.1}",
            sat.name,
            sched.windows.len(),
            sched.mean_duration().minutes(),
            sched
                .mean_period()
                .unwrap_or(Seconds::from_hours(scenario.horizon_hours))
                .hours(),
            eclipse_fraction(&sat.orbit) * 100.0
        );
    }

    // the same 24 h capture trace through the DES under each routing
    // policy: a one-axis sweep (ILPB solves throughout)
    let spec = SweepSpec {
        name: "constellation-study".to_string(),
        seed: 0xC0457,
        replications: 1,
        base: scenario,
        axes: Axes {
            routing: vec![
                "round-robin".to_string(),
                "least-loaded".to_string(),
                "contact-aware".to_string(),
            ],
            ..Axes::default()
        },
    };
    let result = exp::run_sweep(&spec, exp::default_threads())?;
    println!(
        "\nrouting {} captures ({:.1}-{:.1} GB) through the fleet DES ({} cells):",
        result.cells[0].submitted,
        spec.base.data_gb_lo,
        spec.base.data_gb_hi,
        result.cells.len()
    );
    print!("{}", exp::comparison_table(&result, "routing")?);

    println!(
        "\nContact-aware routing sends downlink-heavy work to the satellite \
         whose next pass opens soonest; least-loaded balances the processing \
         FIFOs. Both beat round-robin once traffic queues — the closed-form \
         model cannot see any of this, which is what the fleet DES is for."
    );
    Ok(())
}
