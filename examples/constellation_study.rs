//! Constellation study: orbit-derived contact windows + fleet DES.
//!
//! ```bash
//! cargo run --release --example constellation_study
//! ```
//!
//! The paper takes `t_cyc`/`t_con` as given constants and evaluates one
//! satellite in closed form. Here we *derive* per-satellite contact
//! windows from first-principles orbital geometry for a Walker 6/3/1
//! constellation over a real ground-station site, then run the fleet
//! discrete-event simulator end-to-end on them: every capture is routed
//! by the coordinator, solved under live per-satellite telemetry (battery
//! SoC, remaining window, queue depth), processed through that
//! satellite's FIFOs, and downlinked through its own passes. Routing
//! policies are compared on the same trace.

use leo_infer::config::{ContactSource, FleetScenario};
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::orbit::contact::ContactSchedule;
use leo_infer::orbit::eclipse::eclipse_fraction;
use leo_infer::sim::fleet::FleetSimulator;
use leo_infer::solver::SolverRegistry;
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::Seconds;

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    let mut scenario = FleetScenario::walker_631();
    scenario.contact_source = ContactSource::Orbit;
    scenario.horizon_hours = 24.0;
    scenario.interarrival_s = 900.0;
    scenario.data_gb_lo = 0.1;
    scenario.data_gb_hi = 2.0;

    let constellation = scenario.pattern()?.build();
    let gs = scenario.ground_station();
    println!(
        "constellation: {} satellites in {} planes @ {} km over {}",
        scenario.sats, scenario.planes, scenario.altitude_km, gs.name
    );

    // per-satellite geometry over the scenario horizon
    println!(
        "\n{:<10} {:>8} {:>12} {:>12} {:>10}",
        "sat", "passes", "t_con(min)", "t_cyc(h)", "eclipse%"
    );
    for sat in &constellation.satellites {
        let sched = ContactSchedule::compute(
            &sat.orbit,
            &gs,
            scenario.horizon_hours * 3600.0,
            30.0,
        );
        println!(
            "{:<10} {:>8} {:>12.1} {:>12.2} {:>10.1}",
            sat.name,
            sched.windows.len(),
            sched.mean_duration().minutes(),
            sched
                .mean_period()
                .unwrap_or(Seconds::from_hours(scenario.horizon_hours))
                .hours(),
            eclipse_fraction(&sat.orbit) * 100.0
        );
    }

    // the same 24 h capture trace through the DES under each routing policy
    let mut rng = Pcg64::seeded(0xC0457);
    let trace = scenario.workload().generate(scenario.horizon(), &mut rng);
    let profile = ModelProfile::sampled(10, &mut rng);
    println!(
        "\nrouting {} captures ({:.1}-{:.1} GB) through the fleet DES:",
        trace.len(),
        scenario.data_gb_lo,
        scenario.data_gb_hi
    );
    println!(
        "{:<14} {:>9} {:>9} {:>11} {:>13} {:>10} {:>12}",
        "policy", "completed", "rejected", "unfinished", "mean lat(s)", "down(GB)", "per-sat done"
    );
    for routing in ["round-robin", "least-loaded", "contact-aware"] {
        let mut scen = scenario.clone();
        scen.routing = routing.to_string();
        let engine = SolverRegistry::engine("ilpb")?;
        let result = FleetSimulator::new(scen.sim_config(profile.clone())?).run(&trace, &engine)?;
        let m = &result.metrics;
        let per_sat: Vec<u64> = m.per_sat().iter().map(|s| s.completed).collect();
        println!(
            "{:<14} {:>9} {:>9} {:>11} {:>13.1} {:>10.2} {:>12}",
            routing,
            m.completed(),
            m.rejected(),
            m.unfinished,
            m.mean_latency().value(),
            m.total_downlinked.gb(),
            format!("{per_sat:?}")
        );
    }

    println!(
        "\nContact-aware routing sends downlink-heavy work to the satellite \
         whose next pass opens soonest; least-loaded balances the processing \
         FIFOs. Both beat round-robin once traffic queues — the closed-form \
         model cannot see any of this, which is what the fleet DES is for."
    );
    Ok(())
}
