//! Constellation study: orbit-derived contact parameters + fleet routing.
//!
//! ```bash
//! cargo run --release --example constellation_study
//! ```
//!
//! The paper takes `t_cyc`/`t_con` as given constants. Here we *derive*
//! them from first-principles orbital geometry for a Walker constellation
//! over a real ground-station site, feed the fitted contact pattern into
//! the offloading model, and compare routing policies across the fleet.

use leo_infer::config::Scenario;
use leo_infer::coordinator::router::{Router, RoutingPolicy};
use leo_infer::coordinator::state::{ClusterState, SatelliteInfo};
use leo_infer::dnn::profile::ModelProfile;
use leo_infer::orbit::constellation::WalkerPattern;
use leo_infer::orbit::contact::ContactSchedule;
use leo_infer::orbit::eclipse::eclipse_fraction;
use leo_infer::orbit::geometry::GroundStation;
use leo_infer::sim::workload::{PoissonWorkload, Request, SizeDist};
use leo_infer::solver::{SolveRequest, SolverRegistry};
use leo_infer::util::rng::Pcg64;
use leo_infer::util::units::{Bytes, Seconds};

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();

    // Tiansuan-like: 6 satellites, 3 planes, 500 km SSO
    let pattern = WalkerPattern::new(6, 3, 1, 97.4, 500.0);
    let constellation = pattern.build();
    let gs = GroundStation::new("beijing", 39.9, 116.4).with_elevation_mask(10.0);
    println!(
        "constellation: {} satellites in {} planes @ {} km over {}",
        pattern.total, pattern.planes, pattern.altitude_km, gs.name
    );

    // derive per-satellite contact schedules over 24 h
    println!("\n{:<10} {:>8} {:>12} {:>12} {:>10}", "sat", "passes", "t_con(min)", "t_cyc(h)", "eclipse%");
    let mut cluster = ClusterState::new();
    let mut schedules = Vec::new();
    for (id, sat) in constellation.satellites.iter().enumerate() {
        let sched = ContactSchedule::compute(&sat.orbit, &gs, 86_400.0, 30.0);
        let t_con = sched.mean_duration();
        let t_cyc = sched.mean_period().unwrap_or(Seconds::from_hours(24.0));
        println!(
            "{:<10} {:>8} {:>12.1} {:>12.2} {:>10.1}",
            sat.name,
            sched.windows.len(),
            t_con.minutes(),
            t_cyc.hours(),
            eclipse_fraction(&sat.orbit) * 100.0
        );
        let mut info = SatelliteInfo::idle(&sat.name);
        info.next_contact_in = sched
            .wait_until_contact(0.0)
            .unwrap_or(Seconds::from_hours(24.0));
        cluster.register(id, info);
        schedules.push((t_cyc, t_con));
    }

    // offloading decisions with orbit-derived contact parameters; one
    // engine serves the whole fleet, so satellites with near-identical
    // contact geometry share cached decisions
    let mut rng = Pcg64::seeded(0xC0457);
    let profile = ModelProfile::sampled(10, &mut rng);
    let engine = SolverRegistry::engine("ilpb")?;
    println!("\nper-satellite ILPB decisions for a 50 GB capture:");
    println!("{:<10} {:>7} {:>14} {:>14} {:>8}", "sat", "split", "latency(s)", "energy(J)", "cached");
    for (id, sat) in constellation.satellites.iter().enumerate() {
        let (t_cyc, t_con) = schedules[id];
        let mut scen = Scenario::tiansuan();
        scen.t_cyc_hours = t_cyc.hours();
        scen.t_con_minutes = t_con.minutes().max(0.5);
        let inst = scen
            .instance_builder(profile.clone())
            .data(Bytes::from_gb(50.0))
            .build()?;
        let out = engine.solve(&SolveRequest::new(inst));
        println!(
            "{:<10} {:>7} {:>14.1} {:>14.1} {:>8}",
            sat.name,
            out.decision.split,
            out.decision.costs.latency.value(),
            out.decision.costs.energy.value(),
            out.cached,
        );
    }

    // routing-policy comparison over a day of traffic
    let workload = PoissonWorkload::new(
        1.0 / 900.0,
        SizeDist::Uniform(Bytes::from_gb(1.0), Bytes::from_gb(10.0)),
    );
    let trace = workload.generate(Seconds::from_hours(24.0), &mut rng);
    println!("\nrouting {} requests across the fleet:", trace.len());
    for policy in [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::ContactAware,
    ] {
        let mut router = Router::new(policy);
        let mut c = cluster.clone();
        let mut assignments = vec![0usize; constellation.len()];
        for req in &trace {
            if let Some(sat) = router.route(req, &c) {
                c.note_enqueue(sat, req.data);
                assignments[sat] += 1;
            }
        }
        let max = *assignments.iter().max().unwrap() as f64;
        let min = *assignments.iter().min().unwrap() as f64;
        println!(
            "  {:<14?} assignments {:?}  (imbalance {:.2}x)",
            policy,
            assignments,
            if min > 0.0 { max / min } else { f64::INFINITY }
        );
    }
    Ok(())
}
