//! Policy frontier: solver × routing × ISL mode over a contact-starved
//! constellation — the scenario-diversity demonstrator for the
//! [`leo_infer::exp`] sweep subsystem.
//!
//! ```bash
//! cargo run --release --example policy_frontier            # full 24 h grid
//! cargo run --release --example policy_frontier -- --smoke # CI-sized run
//! ```
//!
//! 4 solvers × 4 routing policies × 2 ISL modes = 32 configurations of a
//! Walker 8/4/1, every cell scored on the same capture trace (common
//! random numbers). The grid answers a question none of the bespoke
//! studies could: which *combination* of offloading solver, coordinator
//! routing, and ISL fabric sits on the latency/energy frontier — is an
//! optimal split worth less than a relay fabric? Does relay-aware
//! routing only pay off once ISLs exist (it should: without a topology
//! its relay term is inert and it degrades to contact-aware scoring)?
//!
//! The output is the full per-cell table plus per-axis comparisons and
//! the frontier: the configurations no other configuration beats on both
//! mean latency and total energy simultaneously.

use leo_infer::config::FleetScenario;
use leo_infer::exp::{self, Axes, SweepSpec};
use leo_infer::link::isl::IslMode;

fn spec(smoke: bool) -> SweepSpec {
    let mut base = FleetScenario::walker_631();
    base.name = "frontier-8-4-1".to_string();
    base.sats = 8;
    base.planes = 4;
    base.phasing = 1;
    base.isl_rate_mbps = 1000.0;
    base.data_gb_lo = 0.1;
    base.data_gb_hi = 0.5;
    base.horizon_hours = if smoke { 8.0 } else { 24.0 };
    base.interarrival_s = if smoke { 3600.0 } else { 1200.0 };
    SweepSpec {
        name: "policy-frontier".to_string(),
        seed: 0xF407,
        replications: 1,
        base,
        axes: Axes {
            solver: vec![
                "ilpb".to_string(),
                "arg".to_string(),
                "ars".to_string(),
                "greedy".to_string(),
            ],
            routing: vec![
                "round-robin".to_string(),
                "least-loaded".to_string(),
                "contact-aware".to_string(),
                "relay-aware".to_string(),
            ],
            isl: vec![IslMode::Off, IslMode::Grid],
            ..Axes::default()
        },
    }
}

fn main() -> anyhow::Result<()> {
    leo_infer::util::logging::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let spec = spec(smoke);
    println!(
        "policy frontier{}: Walker 8/4/1, {} cells (solver x routing x isl), seed {:#x}\n",
        if smoke { " (smoke)" } else { "" },
        spec.len(),
        spec.seed
    );

    let result = exp::run_sweep(&spec, exp::default_threads())?;

    println!(
        "{:<28} {:>9} {:>11} {:>13} {:>10} {:>7} {:>12}",
        "configuration", "completed", "unfinished", "mean lat(s)", "p95(s)", "relays", "energy(kJ)"
    );
    for c in &result.cells {
        println!(
            "{:<28} {:>9} {:>11} {:>13.0} {:>10.0} {:>7} {:>12.1}",
            format!(
                "{} · {} · isl {}",
                c.cell.solver,
                c.cell.scenario.routing,
                c.cell.scenario.isl.as_str()
            ),
            c.completed,
            c.unfinished,
            c.mean_latency_s(),
            c.p95_latency_s(),
            c.relays,
            c.total_energy_j / 1e3
        );
    }
    for axis in ["solver", "routing", "isl"] {
        println!("\nby {axis}:");
        print!("{}", exp::comparison_table(&result, axis)?);
    }

    // the latency/energy frontier among cells that completed work: a cell
    // is dominated if some other cell is at least as good on both axes
    // and strictly better on one
    let served: Vec<_> = result.cells.iter().filter(|c| c.completed > 0).collect();
    anyhow::ensure!(!served.is_empty(), "the grid must complete work somewhere");
    let mut frontier: Vec<_> = served
        .iter()
        .filter(|c| {
            !served.iter().any(|o| {
                o.mean_latency_s() <= c.mean_latency_s()
                    && o.total_energy_j <= c.total_energy_j
                    && (o.mean_latency_s() < c.mean_latency_s()
                        || o.total_energy_j < c.total_energy_j)
            })
        })
        .collect();
    frontier.sort_by(|a, b| a.mean_latency_s().partial_cmp(&b.mean_latency_s()).unwrap());
    println!("\nlatency/energy frontier (no config beats these on both axes):");
    for c in &frontier {
        println!(
            "  {} · {} · isl {:<5} — {:.0} s mean, {:.1} kJ",
            c.cell.solver,
            c.cell.scenario.routing,
            c.cell.scenario.isl.as_str(),
            c.mean_latency_s(),
            c.total_energy_j / 1e3
        );
    }
    anyhow::ensure!(!frontier.is_empty(), "a non-empty grid has a frontier");

    // relay-aware routing must be inert without a topology: with isl off
    // it can differ from contact-aware only through solver tie-breaks,
    // never through relays
    for c in &result.cells {
        if c.cell.scenario.isl == IslMode::Off {
            anyhow::ensure!(
                c.relays == 0,
                "bent-pipe cells cannot relay (cell {})",
                c.cell.index
            );
        }
    }
    println!("\nOK: frontier computed over {} served configurations.", served.len());
    Ok(())
}
