"""L1: Pallas tiled matmul kernel — the compute hot-spot of every conv and
dense stage of RSNet.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
(M/BM, N/BN, K/BK); for each (i, j) output tile the innermost grid
dimension walks the K slabs, accumulating into the f32 output block that
stays resident in VMEM across revisits. BlockSpec expresses the HBM→VMEM
schedule a CUDA kernel would express with threadblocks + shared memory;
128×128 blocks match the MXU systolic array.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel runs through the Pallas interpreter and lowers
to plain HLO. Real-TPU performance is *estimated* from the BlockSpec's VMEM
footprint and MXU utilization below (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped defaults. BM=BN=128 matches the 128x128 systolic array;
# BK=128 keeps each operand slab at 64 KiB f32.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (BM, BN) output tile; grid dim 2 walks the K slabs and the
    output block accumulates across revisits."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    del n_k  # kept in the signature for symmetry with scratch variants


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr = rows - x.shape[0]
    pc = cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """``x @ y`` via the Pallas tile kernel.

    Shapes need not be multiples of the block size: operands are padded to
    the block lattice and the result sliced back (padding contributes zeros
    to the accumulation, so the numerics are exact).
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {y.shape}")
    if x.shape[1] != y.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    # shrink blocks for small problems to limit padding waste
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    # Adaptive M-blocking (§Perf): conv-via-im2col GEMMs are tall and
    # skinny (M = N·OH·OW ≫ K·N). When the K×N tile is small the whole
    # reduction fits beside a much taller row block, so enlarge BM — this
    # keeps the grid shallow (fewer HBM round-trips on TPU; 23× less
    # per-step overhead under the interpreter) while staying ≪ 16 MiB
    # VMEM. Measured on the batch-8 conv1 GEMM (32768×27×16):
    # 185 ms → 7.7 ms interpret-mode (see EXPERIMENTS.md §Perf).
    if n <= 128:
        if k <= 128:
            # K×N tile ≤ 64 KiB: a BM=8192 row block keeps total VMEM
            # ≈ 2.7 MiB (see vmem_bytes)
            bm = min(_round_up(m, 8), max(bm, 8192))
        elif k <= 512:
            # mid-K conv shapes (RSNet conv2/conv3: K = 144/288):
            # BM=4096 with BK=128 slabs ≈ 6.3 MiB VMEM
            bm = min(_round_up(m, 8), max(bm, 4096))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def vmem_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> int:
    """VMEM footprint of one grid step (two operand blocks + resident
    output block, double-buffered operands), for the §Perf roofline
    estimate."""
    f32 = 4
    return (2 * (bm * bk + bk * bn) + bm * bn) * f32


def mxu_utilization(
    m: int,
    k: int,
    n: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> float:
    """Fraction of MXU-issued MACs doing useful (non-padding) work — the
    §Perf efficiency estimate for a given problem shape."""
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    return (m * k * n) / float(mp * kp * np_)
