"""L1: conv2d as im2col + the Pallas tiled matmul.

TPU adaptation of the conv hot-spot (DESIGN.md §Hardware-Adaptation): where
a CUDA kernel would tile the implicit GEMM over threadblocks with shared-
memory staging, we materialize the im2col patches with XLA (which fuses the
gather into the surrounding HLO) and feed the (N·OH·OW, C·KH·KW) ×
(C·KH·KW, OC) GEMM to the MXU-shaped Pallas kernel from ``matmul.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, pad: int) -> jax.Array:
    """(N, C, H, W) → (N·OH·OW, C·KH·KW) patch matrix."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    # gather patches: for each (dy, dx) offset take a strided slice
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            sl = xp[:, :, dy : dy + stride * oh : stride, dx : dx + stride * ow : stride]
            cols.append(sl)  # (N, C, OH, OW)
    # (KH·KW, N, C, OH, OW) → (N, OH, OW, C, KH·KW) → (N·OH·OW, C·KH·KW)
    stacked = jnp.stack(cols, axis=0)
    stacked = stacked.transpose(1, 3, 4, 2, 0)
    return stacked.reshape(n * oh * ow, c * kh * kw), oh, ow


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    padding: int = 1,
) -> jax.Array:
    """NCHW convolution through the Pallas GEMM.

    x: (N, C, H, W); w: (OC, C, KH, KW); b: (OC,) → (N, OC, OH, OW).
    """
    n = x.shape[0]
    oc, c, kh, kw = w.shape
    if x.shape[1] != c:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")
    patches, oh, ow = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(oc, c * kh * kw).T  # (C·KH·KW, OC)
    out = matmul(patches, wmat) + b[None, :]
    return out.reshape(n, oh, ow, oc).transpose(0, 3, 1, 2)
