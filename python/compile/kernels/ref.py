"""Pure-jnp oracles for the Pallas kernels.

Everything here is the *specification*: pytest asserts the kernels in
``matmul.py`` / ``conv2d.py`` match these to float tolerance across
hypothesis-driven shape sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain jnp matmul in f32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """NCHW conv oracle via lax.conv_general_dilated.

    x: (N, C, H, W); w: (OC, C, KH, KW); b: (OC,).
    """
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def maxpool2_ref(x: jax.Array) -> jax.Array:
    """2×2/2 max pooling, NCHW."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def global_avg_pool_ref(x: jax.Array) -> jax.Array:
    """GAP to (N, C)."""
    return x.mean(axis=(2, 3))


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (N, F) @ w: (F, O) + b."""
    return matmul_ref(x, w) + b[None, :]
