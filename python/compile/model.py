"""L2: RSNet-9 — the remote-sensing scene classifier, staged per subtask.

Mirrors ``rust/src/dnn/models.rs::rsnet9()`` layer for layer; the AOT
manifest's measured per-stage activation sizes are cross-checked against
that analytic profile by rust integration tests, so **keep the two
definitions in lockstep**.

Every stage is an independent jax function (one subtask `M_k` in the
paper): the coordinator can run any prefix on the "satellite" PJRT client,
serialize the boundary activation (the downlinked payload), and resume on
the "cloud" client. Weights are baked into each stage as constants
(deterministic seed), so the compiled artifacts are self-contained.

Conv and dense stages route through the L1 Pallas kernels.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.conv2d import conv2d
from .kernels.matmul import matmul

INPUT_SHAPE = (3, 64, 64)  # CHW, EuroSAT-style RGB tile
NUM_CLASSES = 10
SEED = 20230715


def _init_weights() -> dict:
    """Deterministic He-initialized weights (numpy, baked as constants)."""
    rng = np.random.default_rng(SEED)

    def conv_w(oc, ic, k):
        fan_in = ic * k * k
        return (
            rng.normal(0.0, np.sqrt(2.0 / fan_in), size=(oc, ic, k, k)).astype(
                np.float32
            ),
            np.zeros(oc, np.float32),
        )

    def dense_w(i, o):
        return (
            rng.normal(0.0, np.sqrt(2.0 / i), size=(i, o)).astype(np.float32),
            np.zeros(o, np.float32),
        )

    w = {}
    w["conv1"] = conv_w(16, 3, 3)
    w["conv2"] = conv_w(32, 16, 3)
    w["conv3"] = conv_w(64, 32, 3)
    w["conv4"] = conv_w(64, 64, 3)
    w["fc"] = dense_w(64, NUM_CLASSES)
    return w


_W = _init_weights()


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


# ---------------------------------------------------------------- stages
# Stage k implements subtask M_{k+1}; shapes are per-batch (N, ...).
# The list index is the split boundary: running stages[0:s] on the
# satellite downlinks stages[s]'s input.


def stage_conv1(x):
    w, b = _W["conv1"]
    return conv2d(x, jnp.asarray(w), jnp.asarray(b), stride=1, padding=1)


def stage_relu1(x):
    return jax.nn.relu(x)


def stage_pool1(x):
    return _maxpool2(x)


def stage_conv2(x):
    w, b = _W["conv2"]
    return conv2d(x, jnp.asarray(w), jnp.asarray(b), stride=1, padding=1)


def stage_relu2(x):
    return jax.nn.relu(x)


def stage_pool2(x):
    return _maxpool2(x)


def stage_conv3(x):
    w, b = _W["conv3"]
    return conv2d(x, jnp.asarray(w), jnp.asarray(b), stride=1, padding=1)


def stage_relu3(x):
    return jax.nn.relu(x)


def stage_pool3(x):
    return _maxpool2(x)


def stage_conv4(x):
    w, b = _W["conv4"]
    return conv2d(x, jnp.asarray(w), jnp.asarray(b), stride=1, padding=1)


def stage_relu4(x):
    return jax.nn.relu(x)


def stage_gap(x):
    return x.mean(axis=(2, 3))


def stage_flatten(x):
    # GAP already flattens to (N, C); kept as an explicit subtask to stay
    # aligned with the rust layer list (Flatten after GlobalAvgPool).
    return x.reshape(x.shape[0], -1)


def stage_fc(x):
    w, b = _W["fc"]
    return matmul(x, jnp.asarray(w)) + jnp.asarray(b)[None, :]


def stage_softmax(x):
    return jax.nn.softmax(x, axis=-1)


STAGES: list[tuple[str, Callable]] = [
    ("conv1", stage_conv1),
    ("relu1", stage_relu1),
    ("pool1", stage_pool1),
    ("conv2", stage_conv2),
    ("relu2", stage_relu2),
    ("pool2", stage_pool2),
    ("conv3", stage_conv3),
    ("relu3", stage_relu3),
    ("pool3", stage_pool3),
    ("conv4", stage_conv4),
    ("relu4", stage_relu4),
    ("gap", stage_gap),
    ("flatten", stage_flatten),
    ("fc", stage_fc),
    ("softmax", stage_softmax),
]


def forward(x: jax.Array) -> jax.Array:
    """Full model: all stages chained."""
    for _, fn in STAGES:
        x = fn(x)
    return x


def forward_reference(x: jax.Array) -> jax.Array:
    """Oracle forward pass that bypasses the Pallas kernels (pure
    lax/jnp) — pytest asserts ``forward == forward_reference``."""
    from .kernels.ref import conv2d_ref, dense_ref

    w1, b1 = _W["conv1"]
    w2, b2 = _W["conv2"]
    w3, b3 = _W["conv3"]
    w4, b4 = _W["conv4"]
    wf, bf = _W["fc"]
    x = jax.nn.relu(conv2d_ref(x, jnp.asarray(w1), jnp.asarray(b1)))
    x = _maxpool2(x)
    x = jax.nn.relu(conv2d_ref(x, jnp.asarray(w2), jnp.asarray(b2)))
    x = _maxpool2(x)
    x = jax.nn.relu(conv2d_ref(x, jnp.asarray(w3), jnp.asarray(b3)))
    x = _maxpool2(x)
    x = jax.nn.relu(conv2d_ref(x, jnp.asarray(w4), jnp.asarray(b4)))
    x = x.mean(axis=(2, 3)).reshape(x.shape[0], -1)
    x = dense_ref(x, jnp.asarray(wf), jnp.asarray(bf))
    return jax.nn.softmax(x, axis=-1)


def stage_shapes(batch: int) -> list[tuple[int, ...]]:
    """Input shape of every stage (index 0 = model input), length K+1
    (the final entry is the model output shape)."""
    shapes = [(batch, *INPUT_SHAPE)]
    x = jnp.zeros(shapes[0], jnp.float32)
    for _, fn in STAGES:
        x = jax.eval_shape(fn, x)
        shapes.append(tuple(x.shape))
        x = jnp.zeros(x.shape, jnp.float32)
    return shapes
