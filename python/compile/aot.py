"""AOT lowering: RSNet stages → HLO text artifacts + manifest.

Run once at build time (``make artifacts``); python never touches the
request path. Each stage of ``model.STAGES`` is lowered independently for
every supported batch size, so the rust coordinator can execute an
arbitrary split: stages ``0..s`` on the "satellite" PJRT client, serialize
the boundary activation, stages ``s..K`` on the "cloud" client.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

The manifest records every stage's input/output shape and byte size — the
*measured* α_k profile that rust cross-checks against its analytic layer
algebra (rust/src/dnn/models.rs::rsnet9).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

BATCH_SIZES = (1, 8)
DTYPE_BYTES = 4  # f32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(fn, in_shape: tuple[int, ...]) -> str:
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def elements(shape: tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "model": "rsnet9",
        "seed": model.SEED,
        "input_chw": list(model.INPUT_SHAPE),
        "num_classes": model.NUM_CLASSES,
        "dtype": "f32",
        "batch_sizes": list(BATCH_SIZES),
        "stages": [],
        "full": {},
    }

    for batch in BATCH_SIZES:
        shapes = model.stage_shapes(batch)
        for k, (name, fn) in enumerate(model.STAGES):
            path = f"stage_b{batch}_{k:02d}_{name}.hlo.txt"
            hlo = lower_stage(fn, shapes[k])
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(hlo)
            manifest["stages"].append(
                {
                    "index": k,
                    "name": name,
                    "batch": batch,
                    "in_shape": list(shapes[k]),
                    "out_shape": list(shapes[k + 1]),
                    "in_bytes": elements(shapes[k]) * DTYPE_BYTES,
                    "out_bytes": elements(shapes[k + 1]) * DTYPE_BYTES,
                    "path": path,
                }
            )
        full_path = f"model_b{batch}_full.hlo.txt"
        with open(os.path.join(out_dir, full_path), "w") as f:
            f.write(lower_stage(model.forward, shapes[0]))
        manifest["full"][str(batch)] = {
            "in_shape": list(shapes[0]),
            "out_shape": list(shapes[-1]),
            "path": full_path,
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    manifest = build(args.out)
    n = len(manifest["stages"])
    print(f"wrote {n} stage artifacts + {len(BATCH_SIZES)} full models to {args.out}")


if __name__ == "__main__":
    main()
