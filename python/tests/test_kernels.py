"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps the shape space (including non-block-aligned and
degenerate sizes); assert_allclose with accumulation-order-aware
tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.conv2d import conv2d, _im2col
from compile.kernels.matmul import matmul, mxu_utilization, vmem_bytes
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=96)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestMatmul:
    @settings(max_examples=60, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_over_shape_sweep(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = rand(rng, m, k), rand(rng, k, n)
        got = matmul(x, y)
        want = ref.matmul_ref(x, y)
        assert got.shape == want.shape
        assert_allclose(got, want, rtol=1e-5, atol=1e-5 * np.sqrt(k))

    @pytest.mark.parametrize(
        "m,k,n",
        [
            (128, 128, 128),   # exactly one MXU tile
            (256, 256, 256),   # multi-tile grid
            (1, 64, 10),       # fc head shape
            (4096, 27, 16),    # conv1 im2col shape (batch 1)
            (130, 257, 129),   # off-by-one vs block lattice
            (1, 1, 1),         # degenerate
        ],
    )
    def test_known_shapes(self, m, k, n):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        x, y = rand(rng, m, k), rand(rng, k, n)
        assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5,
                        atol=1e-5 * np.sqrt(k))

    def test_custom_block_sizes_agree(self):
        rng = np.random.default_rng(7)
        x, y = rand(rng, 200, 100), rand(rng, 100, 50)
        a = matmul(x, y, bm=32, bn=32, bk=32)
        b = matmul(x, y, bm=128, bn=128, bk=128)
        assert_allclose(a, b, rtol=1e-5, atol=1e-4)

    def test_rejects_bad_shapes(self):
        x = jnp.zeros((4, 5), jnp.float32)
        y = jnp.zeros((6, 3), jnp.float32)
        with pytest.raises(ValueError):
            matmul(x, y)
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2, 2, 2), jnp.float32), x)

    def test_vmem_footprint_fits_tpu_core(self):
        # default BlockSpec must fit comfortably in a 16 MiB VMEM core
        assert vmem_bytes() <= 16 * 1024 * 1024 // 4

    def test_mxu_utilization_bounds(self):
        assert mxu_utilization(128, 128, 128) == 1.0
        u = mxu_utilization(130, 27, 16)
        assert 0.0 < u <= 1.0


class TestConv2d:
    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(1, 3),
        c=st.integers(1, 8),
        hw=st.integers(4, 24),
        oc=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_same_conv_matches_lax(self, n, c, hw, oc, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, n, c, hw, hw)
        w = rand(rng, oc, c, 3, 3)
        b = rand(rng, oc)
        got = conv2d(x, w, b, stride=1, padding=1)
        want = ref.conv2d_ref(x, w, b, stride=1, padding="SAME")
        assert got.shape == want.shape
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("stride,pad", [(1, 0), (2, 1), (2, 0)])
    def test_strided_conv(self, stride, pad):
        rng = np.random.default_rng(42)
        x = rand(rng, 2, 4, 16, 16)
        w = rand(rng, 8, 4, 3, 3)
        b = rand(rng, 8)
        got = conv2d(x, w, b, stride=stride, padding=pad)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        ) + b[None, :, None, None]
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_im2col_shape(self):
        x = jnp.arange(2 * 3 * 8 * 8, dtype=jnp.float32).reshape(2, 3, 8, 8)
        patches, oh, ow = _im2col(x, 3, 3, 1, 1)
        assert (oh, ow) == (8, 8)
        assert patches.shape == (2 * 64, 27)

    def test_channel_mismatch_rejected(self):
        with pytest.raises(ValueError):
            conv2d(
                jnp.zeros((1, 3, 8, 8), jnp.float32),
                jnp.zeros((4, 5, 3, 3), jnp.float32),
                jnp.zeros(4, jnp.float32),
            )

    def test_1x1_conv(self):
        rng = np.random.default_rng(9)
        x = rand(rng, 1, 8, 10, 10)
        w = rand(rng, 4, 8, 1, 1)
        b = rand(rng, 4)
        got = conv2d(x, w, b, stride=1, padding=0)
        want = ref.conv2d_ref(x, w, b, stride=1, padding="VALID")
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)
