"""AOT pipeline: lowered HLO artifacts are loadable, numerically faithful,
and the manifest is consistent with the model."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_covers_all_stages_and_batches(self, manifest):
        k = len(model.STAGES)
        assert len(manifest["stages"]) == k * len(manifest["batch_sizes"])
        for batch in manifest["batch_sizes"]:
            idxs = sorted(
                s["index"] for s in manifest["stages"] if s["batch"] == batch
            )
            assert idxs == list(range(k))

    def test_shapes_chain(self, manifest):
        for batch in manifest["batch_sizes"]:
            stages = sorted(
                (s for s in manifest["stages"] if s["batch"] == batch),
                key=lambda s: s["index"],
            )
            for a, b in zip(stages, stages[1:]):
                assert a["out_shape"] == b["in_shape"], a["name"]
            assert stages[0]["in_shape"] == [batch, *model.INPUT_SHAPE]
            assert stages[-1]["out_shape"] == [batch, model.NUM_CLASSES]

    def test_bytes_match_shapes(self, manifest):
        for s in manifest["stages"]:
            assert s["in_bytes"] == int(np.prod(s["in_shape"])) * 4
            assert s["out_bytes"] == int(np.prod(s["out_shape"])) * 4

    def test_artifact_files_exist(self, manifest):
        for s in manifest["stages"]:
            assert os.path.exists(os.path.join(ARTIFACTS, s["path"])), s["path"]
        for info in manifest["full"].values():
            assert os.path.exists(os.path.join(ARTIFACTS, info["path"]))


class TestLoweredNumerics:
    def test_hlo_text_parses_and_mentions_entry(self, manifest):
        s = manifest["stages"][0]
        text = open(os.path.join(ARTIFACTS, s["path"])).read()
        assert "ENTRY" in text and "HloModule" in text

    def test_stage_hlo_parses_with_correct_program_shape(self, manifest):
        # round-trip the emitted text through XLA's HLO parser and check
        # the entry computation's parameter/result shapes against the
        # manifest. (Full re-execution happens on the rust side via the
        # xla crate — `runtime::split` integration tests — which is the
        # actual consumer of these artifacts.)
        from jax._src.lib import xla_client as xc

        for k in (0, 2, 13):  # conv, pool, fc
            s = next(
                x for x in manifest["stages"] if x["batch"] == 1 and x["index"] == k
            )
            text = open(os.path.join(ARTIFACTS, s["path"])).read()
            module = xc._xla.hlo_module_from_text(text)
            comp = xc._xla.XlaComputation(module.as_serialized_hlo_module_proto())
            prog = comp.program_shape()
            params = prog.parameter_shapes()
            assert len(params) == 1, s["name"]
            assert list(params[0].dimensions()) == s["in_shape"], s["name"]
            # lowered with return_tuple=True ⇒ result is a 1-tuple
            (result,) = prog.result_shape().tuple_shapes()
            assert list(result.dimensions()) == s["out_shape"], s["name"]

    def test_elements_helper(self):
        assert aot.elements((2, 3, 4)) == 24
        assert aot.elements((7,)) == 7
