"""L2 model correctness: staged RSNet vs the kernel-free oracle, stage
chaining == full forward, shape bookkeeping, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model


def rand_input(batch, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.normal(size=(batch, *model.INPUT_SHAPE)), jnp.float32
    )


class TestForward:
    @settings(max_examples=10, deadline=None)
    @given(batch=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
    def test_pallas_path_matches_reference(self, batch, seed):
        x = rand_input(batch, seed)
        assert_allclose(
            model.forward(x), model.forward_reference(x), rtol=1e-4, atol=1e-5
        )

    def test_output_is_probability_simplex(self):
        y = model.forward(rand_input(4, 1))
        assert y.shape == (4, model.NUM_CLASSES)
        assert_allclose(y.sum(axis=-1), np.ones(4), rtol=1e-5)
        assert (np.asarray(y) >= 0).all()

    def test_stage_chain_equals_forward(self):
        x = rand_input(2, 3)
        y_full = model.forward(x)
        z = x
        for _, fn in model.STAGES:
            z = fn(z)
        assert_allclose(z, y_full, rtol=0, atol=0)

    def test_any_split_reproduces_full_output(self):
        # the paper's split semantics: prefix then suffix must equal the
        # unsplit forward for EVERY split point
        x = rand_input(1, 4)
        y_full = model.forward(x)
        for s in range(len(model.STAGES) + 1):
            z = x
            for _, fn in model.STAGES[:s]:
                z = fn(z)
            # (boundary activation would be downlinked here)
            for _, fn in model.STAGES[s:]:
                z = fn(z)
            assert_allclose(z, y_full, rtol=0, atol=0, err_msg=f"split {s}")

    def test_deterministic_weights(self):
        # weights are seeded: two separate evaluations agree exactly
        x = rand_input(1, 5)
        assert_allclose(model.forward(x), model.forward(x), rtol=0, atol=0)


class TestShapes:
    def test_stage_shapes_chain(self):
        shapes = model.stage_shapes(2)
        assert len(shapes) == len(model.STAGES) + 1
        assert shapes[0] == (2, *model.INPUT_SHAPE)
        assert shapes[-1] == (2, model.NUM_CLASSES)
        # verify against real evaluation
        x = rand_input(2, 6)
        for (name, fn), expect in zip(model.STAGES, shapes[1:]):
            x = fn(x)
            assert tuple(x.shape) == expect, name

    def test_activation_sizes_monotone_after_pools(self):
        shapes = model.stage_shapes(1)
        sizes = [int(np.prod(s)) for s in shapes]
        # pooling stages shrink (indices of pool outputs: 3, 6, 9)
        assert sizes[3] < sizes[1]
        assert sizes[6] < sizes[3]
        assert sizes[9] < sizes[6]
        # final output is tiny vs input
        assert sizes[-1] < sizes[0] / 100

    def test_matches_rust_analytic_profile(self):
        # mirror of rust/src/dnn/models.rs::rsnet9 expectations
        shapes = model.stage_shapes(1)
        assert shapes[1] == (1, 16, 64, 64)   # conv1
        assert shapes[3] == (1, 16, 32, 32)   # pool1
        assert shapes[6] == (1, 32, 16, 16)   # pool2
        assert shapes[9] == (1, 64, 8, 8)     # pool3
        assert shapes[12] == (1, 64)          # gap
        assert shapes[14] == (1, 10)          # fc
